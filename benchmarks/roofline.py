"""Aggregate the dry-run JSONs into the §Roofline table (EXPERIMENTS.md)."""

from __future__ import annotations

import glob
import json
import os


def load(out_dir: str = "experiments/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_table(recs, mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL_FLOPs | HLO_FLOPs | useful | args GB/dev | temp GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | — | — | — |")
            continue
        ro = r["roofline"]
        mem = r.get("memory", {})
        useful = ro.get("useful_flops_ratio")
        useful_s = f"{useful:.2f}" if useful else "—"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.4g} | {ro['memory_s']:.4g} "
            f"| {ro['collective_s']:.4g} | {ro['dominant'].replace('_s', '')} "
            f"| {ro['model_flops_global']:.3g} | {ro['hlo_flops_global']:.3g} "
            f"| {useful_s} "
            f"| {mem.get('argument_size_in_bytes', 0) / 1e9:.2f} "
            f"| {mem.get('temp_size_in_bytes', 0) / 1e9:.2f} |"
        )
    return "\n".join(lines)


def run(log=print):
    recs = load()
    if not recs:
        log("no dry-run records found; run: python -m repro.launch.dryrun --all")
        return []
    for mesh in ("single", "multi"):
        n = sum(1 for r in recs if r.get("mesh") == mesh)
        if n:
            log(f"\n=== roofline, {mesh}-pod ({n} records) ===")
            log(fmt_table(recs, mesh))
    rows = []
    for r in recs:
        if "roofline" in r:
            ro = r["roofline"]
            rows.append((f"dryrun_{r['arch']}_{r['shape']}_{r['mesh']}",
                         ro["compute_s"] * 1e6,
                         f"dominant={ro['dominant']}"))
    return rows


if __name__ == "__main__":
    run()
