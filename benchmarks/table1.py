"""Table 1 reproduction: MixInstruct quality (BARTScore) per method.

Methods (paper Table 1): each single pool member, Random ensemble,
LLM-BLENDER (full pool + rank-top-k + GEN-FUSER), and MODI at 20% of the
LLM-BLENDER cost.  Quality is BARTScore under the in-framework scorer
(orderings are the reproduction target — DESIGN.md §3).

Trained components are cached under experiments/checkpoints/ so reruns are
cheap; delete that directory to retrain.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import bartscore, make_policy, realized_cost_fraction
from repro.core.fusion import build_fusion_batch
from repro.data import (
    DEFAULT_POOL,
    POOL_NAMES,
    TOKENIZER,
    generate_dataset,
    pool_responses,
    query_cost_matrix,
)
from repro.launch.serve import build_stack, quality_labels
from repro.serve import greedy_generate_encdec
from repro.train import checkpoint

CKPT_DIR = "experiments/checkpoints"


def get_stack(train_steps: int, log=print):
    """Train-or-restore the scorer/fuser/predictor stack."""
    paths = {n: os.path.join(CKPT_DIR, f"{n}.npz") for n in ("scorer", "fuser", "predictor")}
    recs = generate_dataset(3000, seed=0)
    from repro.models import build_model
    from repro.core import build_predictor

    scorer = build_model(configs.get("bartscore-scorer"))
    fuser = build_model(configs.get("gen-fuser"))
    predictor = build_predictor(num_models=len(DEFAULT_POOL))
    if all(checkpoint.exists(p) for p in paths.values()):
        log("[stack] restoring cached checkpoints")
        scorer_p = checkpoint.restore(paths["scorer"], scorer.init(jax.random.key(1)))
        fuser_p = checkpoint.restore(paths["fuser"], fuser.init(jax.random.key(2)))
        pred_p = checkpoint.restore(paths["predictor"], predictor.init(jax.random.key(3)))
    else:
        _, scorer, scorer_p, fuser, fuser_p, predictor, pred_p = build_stack(train_steps, log=log)
        os.makedirs(CKPT_DIR, exist_ok=True)
        checkpoint.save(paths["scorer"], scorer_p)
        checkpoint.save(paths["fuser"], fuser_p)
        checkpoint.save(paths["predictor"], pred_p)
    return recs, scorer, scorer_p, fuser, fuser_p, predictor, pred_p


def score_texts(scorer, scorer_p, recs, texts):
    """BARTScore [Q] of response texts against references."""
    refs = TOKENIZER.pad_batch(
        [TOKENIZER.encode(r.reference, bos=True, eos=True) for r in recs], 32
    )
    mask = (refs != TOKENIZER.pad_id).astype(np.float32)
    # BARTScore conditions on the candidate only (see data.batching)
    cands = TOKENIZER.pad_batch([TOKENIZER.encode(t) for t in texts], 64)
    return np.asarray(
        bartscore(scorer, scorer_p, jnp.asarray(cands), jnp.asarray(refs), jnp.asarray(mask))
    )


def fuse(fuser, fuser_p, recs, responses, mask):
    """GEN-FUSER over the selected subset -> fused texts."""
    q_tokens = TOKENIZER.batch_encode([r.query for r in recs], 64)
    resp_tokens = np.full((len(recs), len(DEFAULT_POOL), 48), TOKENIZER.pad_id, np.int32)
    for i in range(len(recs)):
        for j in range(len(DEFAULT_POOL)):
            if mask[i, j]:
                enc = TOKENIZER.encode(responses[i][j])[:48]
                resp_tokens[i, j, : len(enc)] = enc
    fuse_in = build_fusion_batch(q_tokens, resp_tokens, mask, TOKENIZER.sep_id, 320)
    out = greedy_generate_encdec(fuser, fuser_p, fuse_in, max_new=28)
    return [TOKENIZER.decode(row) for row in out]


def run(n_test: int = 400, train_steps: int = 700, budget: float = 0.2, log=print):
    t0 = time.time()
    _, scorer, scorer_p, fuser, fuser_p, predictor, pred_p = get_stack(train_steps, log=log)
    test = generate_dataset(n_test, seed=12345)
    responses = pool_responses(DEFAULT_POOL, test, seed=99)
    costs = query_cost_matrix(DEFAULT_POOL, test)
    full_cost = costs.sum(1)

    # predicted quality from the query alone (MODI §2.3)
    toks = TOKENIZER.batch_encode([r.query for r in test], 64, cls=True)
    r_hat = np.asarray(predictor.apply(pred_p, jnp.asarray(toks)))

    results = {}

    # single members (Table 1 rows 1-8)
    for j, name in enumerate(POOL_NAMES):
        s = score_texts(scorer, scorer_p, test, [responses[i][j] for i in range(n_test)])
        results[name] = {"bartscore": float(s.mean()), "cost_frac": float((costs[:, j] / full_cost).mean())}

    # Random ensemble of 3 + fuse
    rmask = np.asarray(make_policy("random", k=3, seed=5).select(jnp.asarray(r_hat), jnp.asarray(costs)))
    fused = fuse(fuser, fuser_p, test, responses, rmask)
    s = score_texts(scorer, scorer_p, test, fused)
    results["Random"] = {"bartscore": float(s.mean()),
                         "cost_frac": float(np.asarray(realized_cost_fraction(jnp.asarray(rmask), jnp.asarray(costs))).mean())}

    # LLM-BLENDER: all N invoked (cost O(N)), rank by quality, fuse top-3
    top3 = np.argsort(-r_hat, axis=1)[:, :3]
    bmask = np.zeros_like(rmask)
    for i in range(n_test):
        bmask[i, top3[i]] = True
    fused = fuse(fuser, fuser_p, test, responses, bmask)
    s = score_texts(scorer, scorer_p, test, fused)
    results["LLM-BLENDER"] = {"bartscore": float(s.mean()), "cost_frac": 1.0}  # invokes all N

    # MODI at `budget` x blender cost
    mmask = np.asarray(make_policy("modi", budget=budget).select(jnp.asarray(r_hat), jnp.asarray(costs)))
    fused = fuse(fuser, fuser_p, test, responses, mmask)
    s = score_texts(scorer, scorer_p, test, fused)
    results["MODI"] = {"bartscore": float(s.mean()),
                       "cost_frac": float(np.asarray(realized_cost_fraction(jnp.asarray(mmask), jnp.asarray(costs))).mean())}

    log(f"\nTable 1 reproduction ({n_test} test queries, {time.time()-t0:.0f}s):")
    log(f"{'method':>18} {'BARTScore':>10} {'cost/blender':>13}")
    for k, v in results.items():
        log(f"{k:>18} {v['bartscore']:>10.3f} {v['cost_frac']:>13.2f}")
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/table1.json", "w") as f:
        json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    run()
