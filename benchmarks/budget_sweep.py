"""Budget sweep: fused-response quality vs ε (the bi-objective trade-off the
paper's §2.2 motivates — no table in the paper, but the frontier behind
its '20% of blender cost' operating point)."""

from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core import make_policy, realized_cost_fraction
from repro.data import DEFAULT_POOL, TOKENIZER, generate_dataset, pool_responses, query_cost_matrix
from benchmarks.table1 import fuse, get_stack, score_texts


def run(n_test: int = 200, train_steps: int = 700,
        fractions=(0.05, 0.1, 0.2, 0.35, 0.5, 0.75, 1.0), log=print):
    _, scorer, scorer_p, fuser, fuser_p, predictor, pred_p = get_stack(train_steps, log=log)
    test = generate_dataset(n_test, seed=54321)
    responses = pool_responses(DEFAULT_POOL, test, seed=77)
    costs = query_cost_matrix(DEFAULT_POOL, test)
    toks = TOKENIZER.batch_encode([r.query for r in test], 64, cls=True)
    r_hat = np.asarray(predictor.apply(pred_p, jnp.asarray(toks)))

    rows = []
    log(f"\nBudget sweep ({n_test} queries):")
    log(f"{'eps':>6} {'members':>8} {'cost':>6} {'BARTScore':>10}")
    for frac in fractions:
        mask = np.asarray(make_policy("modi", budget=float(frac)).select(
            jnp.asarray(r_hat), jnp.asarray(costs)))
        fused = fuse(fuser, fuser_p, test, responses, mask)
        s = score_texts(scorer, scorer_p, test, fused).mean()
        cf = float(np.asarray(realized_cost_fraction(jnp.asarray(mask), jnp.asarray(costs))).mean())
        rows.append({"eps": frac, "members": float(mask.sum(1).mean()),
                     "cost_frac": cf, "bartscore": float(s)})
        log(f"{frac:>6.2f} {mask.sum(1).mean():>8.1f} {cf:>6.2f} {float(s):>10.3f}")
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/budget_sweep.json", "w") as f:
        json.dump(rows, f, indent=2)
    return rows


if __name__ == "__main__":
    run()
