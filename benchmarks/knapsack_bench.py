"""Knapsack micro-benchmark: paper Algorithm 1 (host Python) vs the batched
backtrack-free bitmask DP (lax) vs the Pallas kernel (interpret mode on CPU
— kernel-body semantics; TPU timing comes from the roofline, not this host
clock).  Both accelerated paths carry packed uint32 selections with the DP
row, so no [N, Q, B+1] take tensor is ever allocated."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.knapsack import knapsack_reference, knapsack_select
from repro.kernels.knapsack import knapsack_select_pallas


def _time(fn, *args, reps=5):
    fn(*args)  # warm/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(log=print):
    rng = np.random.default_rng(0)
    rows = []
    for q, n, budget in [(16, 8, 256), (64, 8, 256), (256, 8, 256), (64, 16, 256)]:
        profits = jnp.asarray(rng.uniform(0.1, 5.0, (q, n)), jnp.float32)
        costs_np = rng.integers(1, 128, (q, n))
        costs = jnp.asarray(costs_np, jnp.int32)

        def py_ref():
            for qi in range(q):
                knapsack_reference(
                    [{"cost": int(costs_np[qi, i]), "target_score": float(profits[qi, i])}
                     for i in range(n)], budget)
            return jnp.zeros(())

        t_py = _time(lambda: py_ref(), reps=1)
        t_lax = _time(lambda: knapsack_select(profits, costs, budget))
        t_pl = _time(lambda: knapsack_select_pallas(profits, costs, budget))
        rows.append((f"knapsack_q{q}_n{n}", t_lax, f"python={t_py:.0f}us pallas_interp={t_pl:.0f}us"))
        log(f"knapsack q={q} n={n} B={budget}: python={t_py:8.0f}us  "
            f"lax={t_lax:8.0f}us  pallas(interp)={t_pl:8.0f}us")
    return rows


if __name__ == "__main__":
    run()
