"""Cluster-serving benchmark: async dispatch vs sync, submit blocking,
and host-failure recovery over a sharded placement.

Writes ``BENCH_serve_cluster.json``:

* ``submit_p50_s`` / ``submit_p99_s`` — wall time a caller spends inside
  ``Scheduler.submit`` with async dispatch on (acceptance: p99 below one
  batch of service time, i.e. submit never blocks on a batch), with the
  sync scheduler's numbers alongside for contrast;
* ``async_p50_s`` / ``async_p99_s`` vs ``sync_p50_s`` / ``sync_p99_s`` —
  end-to-end request latency through the same steady scenario;
* ``recovery_max_s`` — worst request latency through the host-outage
  scenario (the hedged batch pays the failed attempt plus the
  knapsack re-solve on the survivors), with the unhedged median for
  scale;
* ``fanout_speedup`` — mean batch member-*generation* service time
  (the engine's ``timing["generate_s"]`` phase — the phase fan-out
  parallelizes; fusion is a single-host stage identical either way)
  with sequential routing over fan-out routing (per-host shards on
  concurrent ``HostExecutor`` threads), under a fixed per-call
  simulated device service floor (a real accelerator dispatch releases
  the GIL exactly the way the floor's sleep does); acceptance is
  >= 1.5x on the 8-forced-device fleet with ``fanout_recompiles == 0``;
* ``recovery_ticks`` — logical ticks from the host-outage hedge to the
  host's post-probation revival in the ``host-recovery`` preset, plus
  the share of dispatches that ran with members masked (the window the
  fleet served degraded);
* ``probe_recovery_ticks`` — the same outage-to-revival gap under the
  ``probe-recovery`` preset, where a HealthMonitor's half-open probe
  revives the host at the next probe tick instead of a schedule +
  probation window (acceptance: strictly below ``recovery_ticks``);
* ``straggler_p99_hedged_s`` vs ``straggler_p99_unhedged_s`` — p99
  batch member-generation time with one grey-slow host, with and
  without the fan-out shard deadline (a late shard is cancelled and
  hedged onto a replica host); acceptance: hedging wins with
  ``hedge_recompiles == 0``;
* ``degraded_rate`` — share of responses served as partial ensembles
  (knapsack over survivors, ``degraded=True``) through the host-outage
  preset with ``Scheduler(allow_degraded=True)``;
* ``steady_state_recompiles`` — generate compiles after warm; 0 means
  placement routing reuses every BucketLadder bucket.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
cluster/bench jobs do) to exercise real per-host meshes; on a single
device the placement is logical-only and routes identically.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import List

import jax
import numpy as np

from repro import configs
from repro.core import build_predictor, make_policy
from repro.data import DEFAULT_POOL, generate_dataset
from repro.models import build_model
from repro.serve import (
    ClusterRouter,
    EnsembleServer,
    PlacementPlan,
    Scheduler,
    TrafficSimulator,
    current_dispatch_host,
    preset_scenarios,
    requests_from_records,
)
from repro.serve.traffic import build_arrivals


_STACK = None


class _ServiceFloor:
    """MemberBackend wrapper adding a fixed per-call device service time.

    The behavioural simulator generates in microseconds, so shard
    concurrency has nothing to overlap; a real accelerator generate
    blocks for milliseconds *outside the GIL* — ``sleep`` reproduces
    exactly that profile, making the fan-out/sequential comparison
    measure orchestration, not simulator arithmetic."""

    def __init__(self, inner, service_s: float):
        self.inner = inner
        self.service_s = service_s

    def num_members(self) -> int:
        return self.inner.num_members()

    def generate(self, member_idx, records, max_new_tokens):
        time.sleep(self.service_s)
        return self.inner.generate(member_idx, records, max_new_tokens)

    # forward the optional hooks so warm-up and the recompile gate see
    # through the floor to the real backend
    def warm(self, shapes):
        warm = getattr(self.inner, "warm", None)
        if callable(warm):
            warm(shapes)

    def compiles(self) -> int:
        compiles = getattr(self.inner, "compiles", None)
        return compiles() if callable(compiles) else 0


class _StragglerFloor(_ServiceFloor):
    """Host-aware service floor: one grey host serves every call
    ``slow_s`` wall seconds while the rest serve ``service_s`` — the
    wall-clock straggler the shard-deadline hedge races.  The executing
    host is read from ``current_dispatch_host()`` (set by the router
    around every inner generate), so the same wrapper instance is fast
    or slow purely by where the shard landed."""

    def __init__(self, inner, service_s: float, slow_host: int, slow_s: float):
        super().__init__(inner, service_s)
        self.slow_host = slow_host
        self.slow_s = slow_s

    def generate(self, member_idx, records, max_new_tokens):
        slow = current_dispatch_host() == self.slow_host
        time.sleep(self.slow_s if slow else self.service_s)
        return self.inner.generate(member_idx, records, max_new_tokens)


def _build_server(budget: float, n_hosts: int, policy: str = "modi",
                  fanout: bool = False,
                  service_floor_s: float = 0.0,
                  replicas: int = 1,
                  shard_deadline_s=None,
                  straggler=None) -> EnsembleServer:
    global _STACK
    if _STACK is None:
        pred = build_predictor(num_models=len(DEFAULT_POOL))
        pp = pred.init(jax.random.key(0))
        fuser = build_model(configs.get("gen-fuser"))
        fp = fuser.init(jax.random.key(1))
        _STACK = (pred, pp, fuser, fp)
    pred, pp, fuser, fp = _STACK
    kwargs = {"budget": budget} if policy == "modi" else {}
    server = EnsembleServer(DEFAULT_POOL, make_policy(policy, **kwargs),
                            pred, pp, fuser, fp)
    devices = jax.devices()
    placeable = (len(devices) >= n_hosts and len(devices) % n_hosts == 0)
    plan = PlacementPlan.auto(DEFAULT_POOL, n_hosts=n_hosts, replicas=replicas,
                              devices=devices if placeable else None)
    backend = server.backend
    if straggler is not None:
        backend = _StragglerFloor(backend, service_floor_s,
                                  straggler[0], straggler[1])
    elif service_floor_s > 0:
        backend = _ServiceFloor(backend, service_floor_s)
    server.backend = ClusterRouter(backend, plan=plan, fanout=fanout,
                                   shard_deadline_s=shard_deadline_s)
    return server


def _warm(server: EnsembleServer, batch_size: int) -> int:
    ladder = server.bucket_ladder
    rungs = sorted({ladder.batch_bucket(b) for b in range(1, batch_size + 1)})
    server.warm([(b, server.max_new_tokens) for b in rungs])
    return server.generate_compiles()["total"]


def _drive_submits(sched: Scheduler, scenario, records) -> List[float]:
    """Drive one scenario manually, returning per-call submit wall times."""
    arrivals = build_arrivals(scenario, records)
    durations: List[float] = []
    idx = 0
    while idx < len(arrivals) or sched.pending:
        while idx < len(arrivals) and arrivals[idx][0] <= sched.now:
            t0 = time.perf_counter()
            sched.submit(arrivals[idx][1])
            durations.append(time.perf_counter() - t0)
            idx += 1
        sched.tick()
    sched.join()
    return durations


def run(n_requests: int = 16, batch_size: int = 4, budget: float = 0.2,
        n_hosts: int = 4, out_path: str = "BENCH_serve_cluster.json",
        log=print):
    records = generate_dataset(max(n_requests, 16), seed=1234)
    scenarios = preset_scenarios(n_requests=n_requests)
    steady, outage = scenarios["steady"], scenarios["host-outage"]

    # -- submit blocking (async) vs inline dispatch (sync) ---------------
    server = _build_server(budget, n_hosts)
    warm_compiles = _warm(server, batch_size)
    sched = Scheduler(server, max_batch_size=batch_size, max_wait_ticks=2,
                      sync=False)
    async_submits = _drive_submits(sched, steady, records)
    sched.close()
    async_compiles = server.generate_compiles()["total"]

    server_sync = _build_server(budget, n_hosts)
    _warm(server_sync, batch_size)
    sync_submits = _drive_submits(
        Scheduler(server_sync, max_batch_size=batch_size, max_wait_ticks=2),
        steady, records)

    # -- end-to-end latency, async vs sync -------------------------------
    server_a = _build_server(budget, n_hosts)
    _warm(server_a, batch_size)
    sched_a = Scheduler(server_a, max_batch_size=batch_size, max_wait_ticks=2,
                        sync=False)
    rep_a = TrafficSimulator(sched_a, steady, records).run()
    sched_a.close()
    batch_service = [r.timing["total_s"] for r in rep_a.responses if r is not None]

    server_s = _build_server(budget, n_hosts)
    _warm(server_s, batch_size)
    rep_s = TrafficSimulator(
        Scheduler(server_s, max_batch_size=batch_size, max_wait_ticks=2),
        steady, records).run()

    # -- host-failure recovery --------------------------------------------
    server_f = _build_server(budget, n_hosts)
    _warm(server_f, batch_size)
    sched_f = Scheduler(server_f, max_batch_size=batch_size, max_wait_ticks=2,
                        sync=False)
    rep_f = TrafficSimulator(sched_f, outage, records).run()
    sched_f.close()
    hedged = sorted({r for ev in rep_f.trace if ev["event"] == "host_hedge"
                     for r in ev["reqs"]})
    hedged_walls = [rep_f.wall_latency_s[i] for i in hedged
                    if rep_f.wall_latency_s[i] is not None]
    plain_walls = [w for i, w in enumerate(rep_f.wall_latency_s)
                   if w is not None and i not in hedged]

    # -- fan-out vs sequential batch generation service -------------------
    # llm-blender selects every pool member, so every placement host
    # carries a shard — the comparison measures full cross-host overlap
    # on the member-generation phase (the phase fan-out parallelizes;
    # fusion is a separate single-host stage and identical either way),
    # timed via the engine's own per-phase clock (timing["generate_s"])
    floor_s = 0.02
    service: dict = {}
    fanout_recompiles = 0
    for mode in ("sequential", "fanout"):
        server_x = _build_server(budget, n_hosts, policy="llm-blender",
                                 fanout=(mode == "fanout"),
                                 service_floor_s=floor_s)
        _warm(server_x, batch_size)
        reqs = requests_from_records(records[:batch_size])
        server_x.serve_requests(reqs)  # prime every bucket on this path
        compiles_before = server_x.generate_compiles()["total"]
        times = []
        for _ in range(3):
            out = server_x.serve_requests(reqs)
            times.append(out[0].timing["generate_s"])
        service[mode] = float(np.mean(times))
        if mode == "fanout":
            fanout_recompiles = (server_x.generate_compiles()["total"]
                                 - compiles_before)
            server_x.backend.close()

    # -- host recovery: outage -> probation -> revival --------------------
    server_r = _build_server(budget, n_hosts)
    _warm(server_r, batch_size)
    rep_r = TrafficSimulator(
        Scheduler(server_r, max_batch_size=batch_size, max_wait_ticks=2),
        scenarios["host-recovery"], records).run()
    outage_ticks = [e["tick"] for e in rep_r.trace if e["event"] == "host_hedge"]
    revive_ticks = [e["tick"] for e in rep_r.trace if e["event"] == "revive"]
    dispatches = [e for e in rep_r.trace if e["event"] == "dispatch"]
    masked_dispatches = sum(1 for e in dispatches if e["masked"])
    recovery_ticks = (revive_ticks[0] - outage_ticks[0]
                      if outage_ticks and revive_ticks else -1)

    # -- probe-driven recovery: observed liveness vs the schedule ---------
    # Same outage, same underlying-health return tick as host-recovery,
    # but the HealthMonitor's half-open probe revives the host at the
    # next probe tick — no probation window, so the gap must be strictly
    # smaller than the schedule-driven recovery above.
    server_p = _build_server(budget, n_hosts)
    _warm(server_p, batch_size)
    rep_p = TrafficSimulator(
        Scheduler(server_p, max_batch_size=batch_size, max_wait_ticks=2),
        scenarios["probe-recovery"], records).run()
    probe_outage = [e["tick"] for e in rep_p.trace
                    if e["event"] == "host_hedge"]
    probe_revive = [e["tick"] for e in rep_p.trace
                    if e["event"] == "probe_revive"]
    probe_recovery_ticks = (probe_revive[0] - probe_outage[0]
                            if probe_outage and probe_revive else -1)
    probes_run = sum(1 for e in rep_p.trace if e["event"] == "probe")

    # -- straggler hedging: shard deadline vs riding out the grey host ----
    # One grey host serves every call 10x slower; with a shard deadline
    # the fan-out join cancels the late shard's future and re-runs its
    # unfinished orders on a replica host, so p99 generation time tracks
    # the deadline + a fast re-run instead of the straggler's pace.
    floor_fast, floor_slow, deadline_s = 0.01, 0.15, 0.04
    straggle: dict = {}
    hedge_recompiles = 0
    shard_hedges = 0
    for mode in ("unhedged", "hedged"):
        server_g = _build_server(
            budget, n_hosts, policy="llm-blender", fanout=True,
            service_floor_s=floor_fast, replicas=2,
            shard_deadline_s=(deadline_s if mode == "hedged" else None),
            straggler=(0, floor_slow))
        _warm(server_g, batch_size)
        reqs = requests_from_records(records[:batch_size])
        server_g.serve_requests(reqs)  # prime every bucket on this path
        compiles_before = server_g.generate_compiles()["total"]
        times = []
        for _ in range(3):
            out = server_g.serve_requests(reqs)
            times.append(out[0].timing["generate_s"])
        straggle[mode] = float(np.percentile(times, 99))
        if mode == "hedged":
            hedge_recompiles = (server_g.generate_compiles()["total"]
                                - compiles_before)
            shard_hedges = server_g.backend.stats["shard_hedges"]
        server_g.backend.close()

    # -- graceful degradation: partial ensembles through the outage ------
    # allow_degraded lets the Scheduler serve the survivors' knapsack
    # when a host dies (degraded=True, survivor-cost settlement) instead
    # of failing the batch when hedging is off.
    server_d = _build_server(budget, n_hosts)
    _warm(server_d, batch_size)
    sched_d = Scheduler(server_d, max_batch_size=batch_size, max_wait_ticks=2,
                        hedge=False, allow_degraded=True)
    rep_d = TrafficSimulator(sched_d, outage, records).run()
    degraded_responses = sched_d.stats["degraded_responses"]
    degraded_rate = (degraded_responses / rep_d.served
                     if rep_d.served else 0.0)

    p = lambda xs, q: float(np.percentile(xs, q)) if xs else 0.0  # noqa: E731
    batch_service_mean = float(np.mean(batch_service)) if batch_service else 0.0
    result = {
        "n_hosts": n_hosts,
        "devices": len(jax.devices()),
        "n_requests": n_requests,
        "batch_size": batch_size,
        "submit_p50_s": p(async_submits, 50),
        "submit_p99_s": p(async_submits, 99),
        "submit_p50_sync_s": p(sync_submits, 50),
        "submit_p99_sync_s": p(sync_submits, 99),
        "batch_service_mean_s": batch_service_mean,
        "submit_p99_under_one_batch": p(async_submits, 99) < batch_service_mean,
        "async_p50_s": rep_a.latency_percentiles()["p50_latency_s"],
        "async_p99_s": rep_a.latency_percentiles()["p99_latency_s"],
        "sync_p50_s": rep_s.latency_percentiles()["p50_latency_s"],
        "sync_p99_s": rep_s.latency_percentiles()["p99_latency_s"],
        "host_hedges": rep_f.stats["host_hedges"],
        "recovery_max_s": max(hedged_walls, default=0.0),
        "unhedged_median_s": p(plain_walls, 50),
        "sequential_generate_s": service["sequential"],
        "fanout_generate_s": service["fanout"],
        "fanout_speedup": (service["sequential"] / service["fanout"]
                           if service["fanout"] > 0 else 0.0),
        "fanout_service_floor_s": floor_s,
        "fanout_recompiles": fanout_recompiles,
        "recovery_outage_tick": outage_ticks[0] if outage_ticks else -1,
        "recovery_revive_tick": revive_ticks[0] if revive_ticks else -1,
        "recovery_ticks": recovery_ticks,
        "recovery_masked_dispatch_share": (
            masked_dispatches / len(dispatches) if dispatches else 0.0),
        "recovery_served": rep_r.served,
        "probe_outage_tick": probe_outage[0] if probe_outage else -1,
        "probe_revive_tick": probe_revive[0] if probe_revive else -1,
        "probe_recovery_ticks": probe_recovery_ticks,
        "probes_run": probes_run,
        "probe_beats_schedule": (probe_recovery_ticks >= 0
                                 and probe_recovery_ticks < recovery_ticks),
        "probe_recovery_served": rep_p.served,
        "straggler_p99_unhedged_s": straggle["unhedged"],
        "straggler_p99_hedged_s": straggle["hedged"],
        "hedge_p99_win": straggle["hedged"] < straggle["unhedged"],
        "shard_deadline_s": deadline_s,
        "shard_hedges": shard_hedges,
        "hedge_recompiles": hedge_recompiles,
        "degraded_responses": degraded_responses,
        "degraded_rate": degraded_rate,
        "degraded_served": rep_d.served,
        "compiles_after_warm": warm_compiles,
        "compiles_final": async_compiles,
        "steady_state_recompiles": async_compiles - warm_compiles,
        "backend": "sim+cluster",
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    log(f"wrote {out_path}: submit_p99={result['submit_p99_s']*1e6:.0f}us "
        f"(sync {result['submit_p99_sync_s']*1e6:.0f}us) "
        f"batch_service={batch_service_mean*1e3:.1f}ms "
        f"fanout_speedup={result['fanout_speedup']:.2f}x "
        f"recovery_ticks={result['recovery_ticks']} "
        f"probe_recovery_ticks={result['probe_recovery_ticks']} "
        f"straggler_p99={straggle['hedged']*1e3:.1f}ms "
        f"(unhedged {straggle['unhedged']*1e3:.1f}ms) "
        f"degraded_rate={result['degraded_rate']:.2f} "
        f"recovery_max={result['recovery_max_s']*1e3:.1f}ms "
        f"recompiles={result['steady_state_recompiles']}")
    return [
        ("serve_cluster_submit_p99", result["submit_p99_s"] * 1e6,
         f"sync={result['submit_p99_sync_s']*1e6:.0f}us "
         f"batch={batch_service_mean*1e6:.0f}us "
         f"under_one_batch={result['submit_p99_under_one_batch']}"),
        ("serve_cluster_fanout", result["fanout_generate_s"] * 1e6,
         f"sequential={result['sequential_generate_s']*1e6:.0f}us "
         f"speedup={result['fanout_speedup']:.2f}x "
         f"recompiles={result['fanout_recompiles']}"),
        ("serve_cluster_recovery", result["recovery_max_s"] * 1e6,
         f"host_hedges={result['host_hedges']} "
         f"recovery_ticks={result['recovery_ticks']} "
         f"unhedged_p50={result['unhedged_median_s']*1e6:.0f}us "
         f"recompiles={result['steady_state_recompiles']}"),
        ("serve_cluster_probe_recovery", result["probe_recovery_ticks"],
         f"schedule_ticks={result['recovery_ticks']} "
         f"probes={result['probes_run']} "
         f"beats_schedule={result['probe_beats_schedule']}"),
        ("serve_cluster_straggler_hedge",
         result["straggler_p99_hedged_s"] * 1e6,
         f"unhedged={result['straggler_p99_unhedged_s']*1e6:.0f}us "
         f"shard_hedges={result['shard_hedges']} "
         f"p99_win={result['hedge_p99_win']} "
         f"recompiles={result['hedge_recompiles']}"),
        ("serve_cluster_degraded", result["degraded_rate"],
         f"degraded={result['degraded_responses']} "
         f"served={result['degraded_served']}"),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--budget", type=float, default=0.2)
    ap.add_argument("--hosts", type=int, default=4)
    args = ap.parse_args()
    run(n_requests=args.n_requests, batch_size=args.batch_size,
        budget=args.budget, n_hosts=args.hosts)
