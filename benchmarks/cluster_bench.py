"""Cluster-serving benchmark: async dispatch vs sync, submit blocking,
and host-failure recovery over a sharded placement.

Writes ``BENCH_serve_cluster.json``:

* ``submit_p50_s`` / ``submit_p99_s`` — wall time a caller spends inside
  ``Scheduler.submit`` with async dispatch on (acceptance: p99 below one
  batch of service time, i.e. submit never blocks on a batch), with the
  sync scheduler's numbers alongside for contrast;
* ``async_p50_s`` / ``async_p99_s`` vs ``sync_p50_s`` / ``sync_p99_s`` —
  end-to-end request latency through the same steady scenario;
* ``recovery_max_s`` — worst request latency through the host-outage
  scenario (the hedged batch pays the failed attempt plus the
  knapsack re-solve on the survivors), with the unhedged median for
  scale;
* ``steady_state_recompiles`` — generate compiles after warm; 0 means
  placement routing reuses every BucketLadder bucket.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
cluster/bench jobs do) to exercise real per-host meshes; on a single
device the placement is logical-only and routes identically.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import List

import jax
import numpy as np

from repro import configs
from repro.core import build_predictor, make_policy
from repro.data import DEFAULT_POOL, generate_dataset
from repro.models import build_model
from repro.serve import (
    ClusterRouter,
    EnsembleServer,
    PlacementPlan,
    Scheduler,
    TrafficSimulator,
    preset_scenarios,
)
from repro.serve.traffic import build_arrivals


_STACK = None


def _build_server(budget: float, n_hosts: int) -> EnsembleServer:
    global _STACK
    if _STACK is None:
        pred = build_predictor(num_models=len(DEFAULT_POOL))
        pp = pred.init(jax.random.key(0))
        fuser = build_model(configs.get("gen-fuser"))
        fp = fuser.init(jax.random.key(1))
        _STACK = (pred, pp, fuser, fp)
    pred, pp, fuser, fp = _STACK
    server = EnsembleServer(DEFAULT_POOL, make_policy("modi", budget=budget),
                            pred, pp, fuser, fp)
    devices = jax.devices()
    placeable = (len(devices) >= n_hosts and len(devices) % n_hosts == 0)
    plan = PlacementPlan.auto(DEFAULT_POOL, n_hosts=n_hosts,
                              devices=devices if placeable else None)
    server.backend = ClusterRouter(server.backend, plan=plan)
    return server


def _warm(server: EnsembleServer, batch_size: int) -> int:
    ladder = server.bucket_ladder
    rungs = sorted({ladder.batch_bucket(b) for b in range(1, batch_size + 1)})
    server.warm([(b, server.max_new_tokens) for b in rungs])
    return server.generate_compiles()["total"]


def _drive_submits(sched: Scheduler, scenario, records) -> List[float]:
    """Drive one scenario manually, returning per-call submit wall times."""
    arrivals = build_arrivals(scenario, records)
    durations: List[float] = []
    idx = 0
    while idx < len(arrivals) or sched.pending:
        while idx < len(arrivals) and arrivals[idx][0] <= sched.now:
            t0 = time.perf_counter()
            sched.submit(arrivals[idx][1])
            durations.append(time.perf_counter() - t0)
            idx += 1
        sched.tick()
    sched.join()
    return durations


def run(n_requests: int = 16, batch_size: int = 4, budget: float = 0.2,
        n_hosts: int = 4, out_path: str = "BENCH_serve_cluster.json",
        log=print):
    records = generate_dataset(max(n_requests, 16), seed=1234)
    scenarios = preset_scenarios(n_requests=n_requests)
    steady, outage = scenarios["steady"], scenarios["host-outage"]

    # -- submit blocking (async) vs inline dispatch (sync) ---------------
    server = _build_server(budget, n_hosts)
    warm_compiles = _warm(server, batch_size)
    sched = Scheduler(server, max_batch_size=batch_size, max_wait_ticks=2,
                      sync=False)
    async_submits = _drive_submits(sched, steady, records)
    sched.close()
    async_compiles = server.generate_compiles()["total"]

    server_sync = _build_server(budget, n_hosts)
    _warm(server_sync, batch_size)
    sync_submits = _drive_submits(
        Scheduler(server_sync, max_batch_size=batch_size, max_wait_ticks=2),
        steady, records)

    # -- end-to-end latency, async vs sync -------------------------------
    server_a = _build_server(budget, n_hosts)
    _warm(server_a, batch_size)
    sched_a = Scheduler(server_a, max_batch_size=batch_size, max_wait_ticks=2,
                        sync=False)
    rep_a = TrafficSimulator(sched_a, steady, records).run()
    sched_a.close()
    batch_service = [r.timing["total_s"] for r in rep_a.responses if r is not None]

    server_s = _build_server(budget, n_hosts)
    _warm(server_s, batch_size)
    rep_s = TrafficSimulator(
        Scheduler(server_s, max_batch_size=batch_size, max_wait_ticks=2),
        steady, records).run()

    # -- host-failure recovery --------------------------------------------
    server_f = _build_server(budget, n_hosts)
    _warm(server_f, batch_size)
    sched_f = Scheduler(server_f, max_batch_size=batch_size, max_wait_ticks=2,
                        sync=False)
    rep_f = TrafficSimulator(sched_f, outage, records).run()
    sched_f.close()
    hedged = sorted({r for ev in rep_f.trace if ev["event"] == "host_hedge"
                     for r in ev["reqs"]})
    hedged_walls = [rep_f.wall_latency_s[i] for i in hedged
                    if rep_f.wall_latency_s[i] is not None]
    plain_walls = [w for i, w in enumerate(rep_f.wall_latency_s)
                   if w is not None and i not in hedged]

    p = lambda xs, q: float(np.percentile(xs, q)) if xs else 0.0  # noqa: E731
    batch_service_mean = float(np.mean(batch_service)) if batch_service else 0.0
    result = {
        "n_hosts": n_hosts,
        "devices": len(jax.devices()),
        "n_requests": n_requests,
        "batch_size": batch_size,
        "submit_p50_s": p(async_submits, 50),
        "submit_p99_s": p(async_submits, 99),
        "submit_p50_sync_s": p(sync_submits, 50),
        "submit_p99_sync_s": p(sync_submits, 99),
        "batch_service_mean_s": batch_service_mean,
        "submit_p99_under_one_batch": p(async_submits, 99) < batch_service_mean,
        "async_p50_s": rep_a.latency_percentiles()["p50_latency_s"],
        "async_p99_s": rep_a.latency_percentiles()["p99_latency_s"],
        "sync_p50_s": rep_s.latency_percentiles()["p50_latency_s"],
        "sync_p99_s": rep_s.latency_percentiles()["p99_latency_s"],
        "host_hedges": rep_f.stats["host_hedges"],
        "recovery_max_s": max(hedged_walls, default=0.0),
        "unhedged_median_s": p(plain_walls, 50),
        "compiles_after_warm": warm_compiles,
        "compiles_final": async_compiles,
        "steady_state_recompiles": async_compiles - warm_compiles,
        "backend": "sim+cluster",
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    log(f"wrote {out_path}: submit_p99={result['submit_p99_s']*1e6:.0f}us "
        f"(sync {result['submit_p99_sync_s']*1e6:.0f}us) "
        f"batch_service={batch_service_mean*1e3:.1f}ms "
        f"recovery_max={result['recovery_max_s']*1e3:.1f}ms "
        f"recompiles={result['steady_state_recompiles']}")
    return [
        ("serve_cluster_submit_p99", result["submit_p99_s"] * 1e6,
         f"sync={result['submit_p99_sync_s']*1e6:.0f}us "
         f"batch={batch_service_mean*1e6:.0f}us "
         f"under_one_batch={result['submit_p99_under_one_batch']}"),
        ("serve_cluster_recovery", result["recovery_max_s"] * 1e6,
         f"host_hedges={result['host_hedges']} "
         f"unhedged_p50={result['unhedged_median_s']*1e6:.0f}us "
         f"recompiles={result['steady_state_recompiles']}"),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--budget", type=float, default=0.2)
    ap.add_argument("--hosts", type=int, default=4)
    args = ap.parse_args()
    run(n_requests=args.n_requests, batch_size=args.batch_size,
        budget=args.budget, n_hosts=args.hosts)
