"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV rows.  Table 1 / budget-sweep train
the paper stack on first run (cached in experiments/checkpoints/).
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller eval sets / training")
    args = ap.parse_args()
    steps = 300 if args.fast else 500
    n1 = 120 if args.fast else 400
    n2 = 60 if args.fast else 200

    rows = []

    from benchmarks import knapsack_bench

    print("\n### knapsack microbenchmark (paper Algorithm 1)")
    rows += knapsack_bench.run()

    from benchmarks import table1

    print("\n### Table 1 reproduction")
    t1 = table1.run(n_test=n1, train_steps=steps)
    rows.append(("table1_modi_bartscore", 0.0,
                 f"modi={t1['MODI']['bartscore']:.3f}@{t1['MODI']['cost_frac']:.2f}x "
                 f"blender={t1['LLM-BLENDER']['bartscore']:.3f}@1.0x"))

    from benchmarks import budget_sweep

    print("\n### budget sweep (bi-objective frontier)")
    bs = budget_sweep.run(n_test=n2, train_steps=steps)
    rows.append(("budget_sweep_points", 0.0,
                 " ".join(f"{r['eps']:.2f}:{r['bartscore']:.2f}" for r in bs)))

    from benchmarks import roofline

    print("\n### roofline (from dry-run artifacts)")
    rows += roofline.run()

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
