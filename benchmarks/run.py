"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only knapsack,serve]

Prints ``name,us_per_call,derived`` CSV rows.  Table 1 / budget-sweep train
the paper stack on first run (cached in experiments/checkpoints/).

``--only`` selects a comma-separated subset of sections
(knapsack, serve, cluster, table1, sweep, roofline) — the CI bench smoke
job runs ``--fast --only knapsack,serve,cluster`` and uploads the
``BENCH_*.json`` artifacts (BENCH_knapsack.json, BENCH_serve.json,
BENCH_serve_cluster.json) each section writes, so the perf trajectory
accumulates per PR.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SECTIONS = ("knapsack", "serve", "cluster", "table1", "sweep", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller eval sets / training")
    ap.add_argument("--only", type=str, default=None,
                    help=f"comma-separated subset of {', '.join(SECTIONS)}")
    args = ap.parse_args()
    steps = 300 if args.fast else 500
    n1 = 120 if args.fast else 400
    n2 = 60 if args.fast else 200
    selected = set(args.only.split(",")) if args.only else set(SECTIONS)
    unknown = selected - set(SECTIONS)
    if unknown:
        ap.error(f"unknown sections: {', '.join(sorted(unknown))}")

    rows = []

    if "knapsack" in selected:
        from benchmarks import knapsack_bench

        print("\n### knapsack microbenchmark (paper Algorithm 1)")
        kn_rows = knapsack_bench.run()
        rows += kn_rows
        with open("BENCH_knapsack.json", "w") as f:
            json.dump([{"name": n, "us_per_call": us, "derived": d}
                       for n, us, d in kn_rows], f, indent=2)

    if "serve" in selected:
        from benchmarks import serve_bench

        print("\n### serving fast path (Scheduler latency / recompiles)")
        rows += serve_bench.run(
            n_batches=5 if args.fast else 8, batch_size=4,
        )
        print("\n### traffic scenario (continuous batching under load)")
        rows += serve_bench.run_scenario(
            "bursty", n_requests=16 if args.fast else 32,
            out_path="BENCH_serve_scenario.json",
        )

    if "cluster" in selected:
        from benchmarks import cluster_bench

        print("\n### cluster serving (async dispatch / placement / host failover)")
        rows += cluster_bench.run(n_requests=12 if args.fast else 24)

    if "table1" in selected:
        from benchmarks import table1

        print("\n### Table 1 reproduction")
        t1 = table1.run(n_test=n1, train_steps=steps)
        rows.append(("table1_modi_bartscore", 0.0,
                     f"modi={t1['MODI']['bartscore']:.3f}@{t1['MODI']['cost_frac']:.2f}x "
                     f"blender={t1['LLM-BLENDER']['bartscore']:.3f}@1.0x"))

    if "sweep" in selected:
        from benchmarks import budget_sweep

        print("\n### budget sweep (bi-objective frontier)")
        bs = budget_sweep.run(n_test=n2, train_steps=steps)
        rows.append(("budget_sweep_points", 0.0,
                     " ".join(f"{r['eps']:.2f}:{r['bartscore']:.2f}" for r in bs)))

    if "roofline" in selected:
        from benchmarks import roofline

        print("\n### roofline (from dry-run artifacts)")
        rows += roofline.run()

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
