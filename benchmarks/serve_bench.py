"""Serving-path benchmark: per-request latency and recompile counts through
the admission Scheduler, exercising the static-shape fast path end to end
(bucketed jit dispatch + donated decode caches in serve.dispatch).

Writes ``BENCH_serve.json`` so the perf trajectory accumulates per PR:

* ``first_batch_s``   — compile-inclusive latency of the first micro-batch;
* ``steady_state_s``  — median micro-batch latency once buckets are warm;
* ``speedup``         — first/steady (the compile tax the fast path removes
  from every batch after the first);
* ``compiles_after_first`` / ``compiles_final`` — generate-callable compile
  counts; equal means zero recompiles in steady state.
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro import configs
from repro.core import build_predictor, make_policy
from repro.data import DEFAULT_POOL, generate_dataset
from repro.models import build_model
from repro.serve import EnsembleServer, Scheduler, requests_from_records


def run(n_batches: int = 8, batch_size: int = 4, budget: float = 0.2,
        out_path: str = "BENCH_serve.json", log=print):
    pred = build_predictor(num_models=len(DEFAULT_POOL))
    pp = pred.init(jax.random.key(0))
    fuser = build_model(configs.get("gen-fuser"))
    fp = fuser.init(jax.random.key(1))
    server = EnsembleServer(DEFAULT_POOL, make_policy("modi", budget=budget),
                            pred, pp, fuser, fp)
    scheduler = Scheduler(server, max_batch_size=batch_size)

    records = generate_dataset(n_batches * batch_size, seed=1234)
    per_batch_s = []
    compiles_after_first = None
    for k in range(n_batches):
        reqs = requests_from_records(records[k * batch_size:(k + 1) * batch_size])
        t0 = time.perf_counter()
        futures = [scheduler.submit(r) for r in reqs]
        scheduler.flush()
        for f in futures:
            f.result()
        per_batch_s.append(time.perf_counter() - t0)
        if k == 0:
            compiles_after_first = server.generate_compiles()["total"]
        log(f"serve batch {k}: {per_batch_s[-1]*1e3:8.1f} ms  "
            f"compiles={server.generate_compiles()['total']}")

    steady = float(np.median(per_batch_s[1:])) if n_batches > 1 else per_batch_s[0]
    result = {
        "batch_size": batch_size,
        "n_batches": n_batches,
        "per_batch_s": per_batch_s,
        "first_batch_s": per_batch_s[0],
        "steady_state_s": steady,
        "per_request_steady_s": steady / batch_size,
        "speedup": per_batch_s[0] / max(steady, 1e-9),
        "compiles_after_first": compiles_after_first,
        "compiles_final": server.generate_compiles()["total"],
        "fuser_buckets": [list(b) for b in server.fuser_dispatch.buckets]
        if server.fuser_dispatch else [],
        "backend": "sim",
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    log(f"wrote {out_path}: first={result['first_batch_s']*1e3:.1f}ms "
        f"steady={steady*1e3:.1f}ms speedup={result['speedup']:.1f}x "
        f"recompiles_after_warm={result['compiles_final'] - compiles_after_first}")
    rows = [
        ("serve_first_batch", result["first_batch_s"] * 1e6,
         f"compile-inclusive b={batch_size}"),
        ("serve_steady_batch", steady * 1e6,
         f"speedup={result['speedup']:.1f}x "
         f"recompiles={result['compiles_final'] - compiles_after_first}"),
    ]
    return rows


if __name__ == "__main__":
    run()
