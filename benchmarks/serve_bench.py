"""Serving-path benchmark: latency and recompile counts through the
continuous-batching Scheduler, exercising the static-shape fast path end
to end (bucketed jit dispatch + donated decode caches in serve.dispatch).

Two modes, both writing ``BENCH_serve.json`` so the perf trajectory
accumulates per PR:

* default — the micro-batch latency probe from PR 2
  (first/steady-state batch latency, compile counters), plus a streaming
  probe through the persistent in-flight decode state reporting
  ``ttft_ms`` (median time-to-first-token) against the batch-boundary
  baseline, ``decode_step_p99_ms``, and the steady-state
  ``generate_compiles`` gate (must stay 0);
* ``--scenario steady|bursty|heavy-tail|failure`` — drive the
  deterministic traffic simulator (:mod:`repro.serve.traffic`) through
  the deadline-aware Scheduler and report p50/p99 request latency,
  deadline-miss rate, shed rate, hedge counts, and steady-state
  recompiles for that scenario.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import configs
from repro.core import build_predictor, make_policy
from repro.data import DEFAULT_POOL, generate_dataset
from repro.models import build_model
from repro.serve import (
    AdmissionControl,
    EnsembleServer,
    Scheduler,
    TrafficSimulator,
    preset_scenarios,
    requests_from_records,
)


def _build_server(budget: float) -> EnsembleServer:
    pred = build_predictor(num_models=len(DEFAULT_POOL))
    pp = pred.init(jax.random.key(0))
    fuser = build_model(configs.get("gen-fuser"))
    fp = fuser.init(jax.random.key(1))
    return EnsembleServer(DEFAULT_POOL, make_policy("modi", budget=budget),
                          pred, pp, fuser, fp)


def run(n_batches: int = 8, batch_size: int = 4, budget: float = 0.2,
        out_path: str = "BENCH_serve.json", log=print):
    """Micro-batch latency probe (PR 2's metric, kept for trajectory)."""
    server = _build_server(budget)
    scheduler = Scheduler(server, max_batch_size=batch_size)

    records = generate_dataset(n_batches * batch_size, seed=1234)
    per_batch_s = []
    compiles_after_first = None
    for k in range(n_batches):
        reqs = requests_from_records(records[k * batch_size:(k + 1) * batch_size])
        t0 = time.perf_counter()
        futures = [scheduler.submit(r) for r in reqs]
        scheduler.flush()
        for f in futures:
            f.result()
        per_batch_s.append(time.perf_counter() - t0)
        if k == 0:
            compiles_after_first = server.generate_compiles()["total"]
        log(f"serve batch {k}: {per_batch_s[-1]*1e3:8.1f} ms  "
            f"compiles={server.generate_compiles()['total']}")

    steady = float(np.median(per_batch_s[1:])) if n_batches > 1 else per_batch_s[0]

    # --- streaming probe: token-level continuous batching through the
    # persistent in-flight decode state.  TTFT is wall time from batch
    # service start to a request's first fused token; the batch-boundary
    # baseline only surfaces its first token when the whole batch settles,
    # so its TTFT *is* the steady-state batch latency measured above.
    stream_server = _build_server(budget)
    stream_sched = Scheduler(stream_server, max_batch_size=batch_size,
                             stream=True, stream_capacity=batch_size)
    fuser = stream_server.stream_fuser(capacity=batch_size)
    ladder = stream_server.bucket_ladder
    fuser.warm(sorted({ladder.batch_bucket(b)
                       for b in range(1, batch_size + 1)}))
    compiles_after_warm = stream_server.generate_compiles()["total"]
    n_warm_steps = len(fuser.step_wall_s)
    ttft_s = []
    for k in range(n_batches):
        reqs = requests_from_records(records[k * batch_size:(k + 1) * batch_size])
        futures = [stream_sched.submit(r) for r in reqs]
        stream_sched.flush()
        for f in futures:
            f.result()
        ttft_s.extend(f.ttft_s for f in futures if f.ttft_s is not None)
    step_walls = fuser.step_wall_s[n_warm_steps:]
    ttft_ms = float(np.median(ttft_s)) * 1e3 if ttft_s else 0.0
    decode_step_p99_ms = (float(np.percentile(step_walls, 99)) * 1e3
                          if step_walls else 0.0)
    # steady-state recompiles on the streaming path — the continuous-batch
    # acceptance gate (CI fails on > 0)
    stream_compiles = (stream_server.generate_compiles()["total"]
                       - compiles_after_warm)

    result = {
        "batch_size": batch_size,
        "n_batches": n_batches,
        "per_batch_s": per_batch_s,
        "first_batch_s": per_batch_s[0],
        "steady_state_s": steady,
        "per_request_steady_s": steady / batch_size,
        "speedup": per_batch_s[0] / max(steady, 1e-9),
        "compiles_after_first": compiles_after_first,
        "compiles_final": server.generate_compiles()["total"],
        "fuser_buckets": [list(b) for b in server.fuser_dispatch.buckets]
        if server.fuser_dispatch else [],
        "ttft_ms": ttft_ms,
        "ttft_batch_boundary_ms": steady * 1e3,
        "ttft_speedup": (steady * 1e3) / max(ttft_ms, 1e-9),
        "decode_step_p99_ms": decode_step_p99_ms,
        "decode_steps": len(step_walls),
        "generate_compiles": stream_compiles,
        "stream_tokens": stream_sched.stats["stream_tokens"],
        "backend": "sim",
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    log(f"wrote {out_path}: first={result['first_batch_s']*1e3:.1f}ms "
        f"steady={steady*1e3:.1f}ms speedup={result['speedup']:.1f}x "
        f"recompiles_after_warm={result['compiles_final'] - compiles_after_first} "
        f"ttft={ttft_ms:.1f}ms (batch-boundary {steady*1e3:.1f}ms) "
        f"step_p99={decode_step_p99_ms:.2f}ms stream_recompiles={stream_compiles}")
    rows = [
        ("serve_first_batch", result["first_batch_s"] * 1e6,
         f"compile-inclusive b={batch_size}"),
        ("serve_steady_batch", steady * 1e6,
         f"speedup={result['speedup']:.1f}x "
         f"recompiles={result['compiles_final'] - compiles_after_first}"),
        ("serve_stream_ttft", ttft_ms * 1e3,
         f"vs batch-boundary {steady*1e3:.1f}ms "
         f"step_p99={decode_step_p99_ms:.2f}ms "
         f"stream_recompiles={stream_compiles}"),
    ]
    return rows


def run_scenario(scenario_name: str, n_requests: int = 24, batch_size: int = 4,
                 budget: float = 0.2, max_wait_ticks: int = 2,
                 admission_budget: float | None = None,
                 out_path: str = "BENCH_serve.json", log=print):
    """Scenario mode: simulate one named traffic scenario and report the
    serving SLO metrics (p50/p99 latency, deadline-miss rate, shed rate)
    plus steady-state recompile counts."""
    scenarios = preset_scenarios(n_requests=n_requests)
    if scenario_name not in scenarios:
        raise SystemExit(
            f"unknown scenario {scenario_name!r}; pick from "
            f"{', '.join(sorted(scenarios))}")
    scenario = scenarios[scenario_name]
    server = _build_server(budget)
    # warm every rung a scheduler batch can land on, so recompiles measure
    # steady-state behaviour rather than cold-start compiles
    ladder = server.bucket_ladder
    rungs = sorted({ladder.batch_bucket(b) for b in range(1, batch_size + 1)})
    server.warm([(b, server.max_new_tokens) for b in rungs])
    compiles_after_warm = server.generate_compiles()["total"]

    admission = None
    if admission_budget is not None:
        admission = AdmissionControl(window_ticks=max(4, max_wait_ticks * 2),
                                     downgrade_fraction=admission_budget,
                                     downgrade_budget=budget / 2,
                                     shed_fraction=min(1.0, admission_budget * 2))
    scheduler = Scheduler(server, max_batch_size=batch_size,
                          max_wait_ticks=max_wait_ticks, admission=admission)
    records = generate_dataset(max(n_requests, 16), seed=1234)
    t0 = time.perf_counter()
    report = TrafficSimulator(scheduler, scenario, records).run()
    wall = time.perf_counter() - t0

    unresolved = sum(r is None and e is None
                     for r, e in zip(report.responses, report.errors))
    compiles_final = report.compiles["total"]
    result = {
        "scenario": scenario_name,
        "n_requests": report.n,
        "served": report.served,
        "unresolved_futures": unresolved,  # acceptance: must be 0
        "ticks": report.ticks,
        "wall_s": wall,
        **report.latency_percentiles(),
        "deadline_miss_rate": report.deadline_miss_rate,
        "shed_rate": report.shed_rate,
        "hedges": report.stats["hedges"],
        "downgraded": report.stats["downgraded"],
        "dispatched_batches": report.stats["dispatched_batches"],
        "padded_rows": report.stats["padded_rows"],
        "compiles_after_warm": compiles_after_warm,
        "compiles_final": compiles_final,
        "steady_state_recompiles": compiles_final - compiles_after_warm,
        "backend": "sim",
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    log(f"wrote {out_path}: scenario={scenario_name} "
        f"p50={result['p50_latency_s']*1e3:.1f}ms "
        f"p99={result['p99_latency_s']*1e3:.1f}ms "
        f"miss_rate={result['deadline_miss_rate']:.2f} "
        f"shed_rate={result['shed_rate']:.2f} "
        f"recompiles={result['steady_state_recompiles']}")
    return [
        (f"serve_{scenario_name}_p50", result["p50_latency_s"] * 1e6,
         f"p99={result['p99_latency_s']*1e6:.0f}us "
         f"miss={result['deadline_miss_rate']:.2f} "
         f"shed={result['shed_rate']:.2f} "
         f"recompiles={result['steady_state_recompiles']}"),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", type=str, default=None,
                    help="traffic scenario: steady, bursty, heavy-tail, failure")
    ap.add_argument("--n-requests", type=int, default=24)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--budget", type=float, default=0.2)
    ap.add_argument("--max-wait-ticks", type=int, default=2)
    ap.add_argument("--admission-budget", type=float, default=None,
                    help="window downgrade threshold (fraction of full cost)")
    args = ap.parse_args()
    if args.scenario:
        run_scenario(args.scenario, n_requests=args.n_requests,
                     batch_size=args.batch_size, budget=args.budget,
                     max_wait_ticks=args.max_wait_ticks,
                     admission_budget=args.admission_budget)
    else:
        run(batch_size=args.batch_size, budget=args.budget)
