"""Test bootstrap.

The container image does not ship ``hypothesis``; rather than skip the
property tests we install a minimal deterministic stand-in that supports
the subset of the API the suite uses (``given``, ``settings``, and the
``integers`` / ``sampled_from`` / ``text`` / ``floats`` / ``booleans``
strategies).  Each ``@given`` test runs ``max_examples`` seeded draws, so
the suite stays reproducible run-to-run.  When real hypothesis is
installed it is used unchanged.
"""

from __future__ import annotations

import random
import sys
import types

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    _TEXT_ALPHABET = (
        "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789"
        " \t\n!@#$%^&*()-_=+[]{};:'\",.<>/?\\|`~"
        "éüñßøπ中日한🎉𝄞́\ud800"
    )

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    def _integers(min_value=0, max_value=1 << 16):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    def _text(alphabet=_TEXT_ALPHABET, min_size=0, max_size=40):
        alphabet = list(alphabet)

        def draw(rng):
            k = rng.randint(min_size, max_size)
            return "".join(alphabet[rng.randrange(len(alphabet))] for _ in range(k))

        return _Strategy(draw)

    def _lists(elements, min_size=0, max_size=10):
        def draw(rng):
            k = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(k)]

        return _Strategy(draw)

    class _SettingsDecorator:
        def __init__(self, max_examples=20, deadline=None, **_kw):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._max_examples = self.max_examples
            return fn

    def _given(*arg_strategies, **kw_strategies):
        def decorate(fn):
            def runner():
                n = getattr(runner, "_max_examples", 20)
                rng = random.Random(0xC0FFEE)
                for _ in range(n):
                    args = [s.example(rng) for s in arg_strategies]
                    kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return decorate

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = _integers
    strategies.floats = _floats
    strategies.booleans = _booleans
    strategies.sampled_from = _sampled_from
    strategies.text = _text
    strategies.lists = _lists

    shim = types.ModuleType("hypothesis")
    shim.given = _given
    shim.settings = _SettingsDecorator
    shim.strategies = strategies
    shim.__is_repro_shim__ = True

    sys.modules["hypothesis"] = shim
    sys.modules["hypothesis.strategies"] = strategies
