"""MoE dispatch invariants: the capacity-bounded gather/scatter dispatch
must equal the dense masked-einsum reference when capacity is ample, and
degrade only by dropping (never corrupting) when it is not."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models.config import ModelConfig
from repro.models.layers import activation
from repro.models.moe import _capacity, apply_moe, init_moe


def _cfg(num_experts=4, top_k=2, cf=8.0, shared=0, dense_residual=False):
    return ModelConfig(
        name="moe-test", family="moe", num_layers=2, d_model=32,
        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=128,
        num_experts=num_experts, moe_top_k=top_k, moe_d_ff=48,
        num_shared_experts=shared, dense_residual=dense_residual,
        capacity_factor=cf, dtype="float32",
    )


def _dense_reference(p, x, cfg):
    """All-experts masked einsum: exact routing, no capacity."""
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    logits = flat @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    act = activation(cfg.act)
    w = p["experts"]
    h = act(jnp.einsum("td,edf->tef", flat, w["wg"])) * jnp.einsum("td,edf->tef", flat, w["wi"])
    all_out = jnp.einsum("tef,efd->ted", h, w["wo"])  # [T, E, D]
    gate_full = jnp.zeros((b * s, cfg.num_experts))
    for j in range(cfg.moe_top_k):
        gate_full = gate_full + gates[:, j:j+1] * jax.nn.one_hot(idx[:, j], cfg.num_experts)
    y = jnp.einsum("te,ted->td", gate_full, all_out)
    return y.reshape(b, s, d)


@pytest.mark.parametrize("shared,dense_res", [(0, False), (1, False), (0, True)])
def test_dispatch_matches_dense_reference(shared, dense_res):
    cfg = _cfg(shared=shared, dense_residual=dense_res)
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    y, aux = apply_moe(p, x, cfg)
    ref = _dense_reference(p, x, cfg)
    if shared:
        from repro.models.layers import apply_mlp
        ref = ref + apply_mlp(p["shared"], x, cfg.act)
    if dense_res:
        from repro.models.layers import apply_mlp
        ref = ref + apply_mlp(p["dense"], x, cfg.act)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-5, rtol=2e-5)
    assert float(aux) > 0  # load-balance loss well-defined


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), e=st.sampled_from([2, 4, 8]), k=st.sampled_from([1, 2]))
def test_dispatch_property(seed, e, k):
    cfg = _cfg(num_experts=e, top_k=k)
    p = init_moe(jax.random.key(seed % 1000), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(seed), (1, 12, cfg.d_model))
    y, _ = apply_moe(p, x, cfg)
    ref = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=3e-5, rtol=3e-5)


def test_capacity_dropping_only_zeroes_tokens():
    """With capacity 1, dropped tokens contribute 0 from the routed branch
    (not garbage), and kept tokens match the reference exactly."""
    cfg = _cfg(cf=1e-9)  # capacity floor = top_k per expert
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model))
    y, _ = apply_moe(p, x, cfg)
    ref = _dense_reference(p, x, cfg)
    y2, ref2 = np.asarray(y).reshape(-1, cfg.d_model), np.asarray(ref).reshape(-1, cfg.d_model)
    for t in range(y2.shape[0]):
        # each token either matches the reference or is partially/fully dropped
        full = np.allclose(y2[t], ref2[t], atol=3e-5)
        partial_norm = np.linalg.norm(y2[t]) <= np.linalg.norm(ref2[t]) + 1e-4
        assert full or partial_norm


def test_capacity_formula():
    cfg = _cfg(num_experts=4, top_k=2, cf=1.25)
    assert _capacity(64, cfg) == int(64 * 2 / 4 * 1.25)
    assert _capacity(1, cfg) == cfg.moe_top_k  # floor


def test_aux_loss_balanced_vs_skewed():
    """Load-balance loss is ~1 for uniform routing, larger when skewed."""
    cfg = _cfg(num_experts=4, top_k=1)
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(2), (4, 32, cfg.d_model))
    _, aux_rand = apply_moe(p, x, cfg)
    # force total skew: router that always picks expert 0
    p_skew = dict(p)
    router = np.zeros_like(np.asarray(p["router"]))
    router[:, 0] = 10.0
    p_skew["router"] = jnp.asarray(x.mean() * 0 + router)
    _, aux_skew = apply_moe(p_skew, x, cfg)
    assert float(aux_skew) > float(aux_rand)
