"""Paper-core behaviour tests: knapsack, ε-constraint, cost model,
predictor, policies, metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    EpsilonConstraint,
    FullEnsemblePolicy,
    GreedyRatioPolicy,
    ModiPolicy,
    RandomPolicy,
    BestSinglePolicy,
    HybridRouterPolicy,
    build_predictor,
    cost_model_from_config,
    enumerate_pareto,
    knapsack_reference,
    knapsack_select,
    pareto_sweep,
    realized_cost_fraction,
    select_under_budget,
    shift_scores,
)
from repro import configs
from repro.data import DEFAULT_POOL, generate_dataset, query_cost_matrix


# ---------------------------------------------------------------------------
# Knapsack (Algorithm 1)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 10),
    budget=st.integers(4, 200),
    seed=st.integers(0, 2**31 - 1),
    q=st.integers(1, 4),
)
def test_knapsack_matches_paper_algorithm(n, budget, seed, q):
    rng = np.random.default_rng(seed)
    profits = rng.uniform(0.05, 4.0, (q, n)).astype(np.float32)
    costs = rng.integers(1, budget + 20, (q, n)).astype(np.int32)
    sel = np.asarray(knapsack_select(jnp.asarray(profits), jnp.asarray(costs), budget))
    for qi in range(q):
        ref = knapsack_reference(
            [{"cost": int(costs[qi, i]), "target_score": float(profits[qi, i])}
             for i in range(n)], budget)
        ref_val = sum(m["target_score"] for m in ref)
        got_val = float(profits[qi][sel[qi]].sum())
        assert abs(ref_val - got_val) < 1e-4
        assert int(costs[qi][sel[qi]].sum()) <= budget


def test_shift_scores_eq4():
    s = jnp.asarray([-3.2, -2.1, -4.0])
    shifted, alpha = shift_scores(s)
    assert alpha > 4.0  # Eq. 5: alpha > max|score|
    assert bool(jnp.all(shifted > 0))
    with pytest.raises(ValueError):
        shift_scores(s, alpha=3.0)


# ---------------------------------------------------------------------------
# ε-constraint (Eq. 3)
# ---------------------------------------------------------------------------


def test_epsilon_budget_respected():
    rng = np.random.default_rng(0)
    quality = jnp.asarray(rng.uniform(-4, -2, (32, 8)), jnp.float32)
    costs = jnp.asarray(rng.uniform(1e11, 5e12, (32, 8)), jnp.float32)
    for frac in (0.1, 0.2, 0.5):
        mask = select_under_budget(quality, costs, EpsilonConstraint(frac))
        realized = realized_cost_fraction(mask, costs)
        assert bool(jnp.all(realized <= frac + 1e-6)), f"budget violated at eps={frac}"


def test_epsilon_monotone_in_budget():
    """More budget never selects a worse (shifted-profit) solution."""
    rng = np.random.default_rng(1)
    quality = jnp.asarray(rng.uniform(-4, -2, (16, 8)), jnp.float32)
    costs = jnp.asarray(rng.uniform(1e11, 5e12, (16, 8)), jnp.float32)
    profits, _ = shift_scores(quality)
    prev = None
    for frac in (0.05, 0.1, 0.2, 0.4, 0.8):
        mask = select_under_budget(quality, costs, EpsilonConstraint(frac))
        val = jnp.sum(jnp.where(mask, profits, 0.0), axis=1)
        if prev is not None:
            assert bool(jnp.all(val >= prev - 1e-4))
        prev = val


def test_pareto_sweep_on_frontier():
    """Every ε-sweep point is non-dominated among brute-force subsets."""
    rng = np.random.default_rng(3)
    quality = rng.uniform(-4.0, -2.0, 6).astype(np.float32)
    costs = rng.uniform(1.0, 10.0, 6)
    frontier = pareto_sweep(quality, costs, fractions=np.linspace(0.05, 1.0, 30), buckets=512)
    shifted = np.asarray(shift_scores(jnp.asarray(quality))[0])
    truth = enumerate_pareto(shifted, costs)  # (cost, profit, mask)
    total = costs.sum()
    for cf, q, mask in frontier:
        if not mask.any():
            continue
        # no brute-force point strictly dominates (cheaper AND better)
        for tc, tp, tm in truth:
            if tc < cf * total - 1e-9:
                assert tp <= q + 1e-3, (cf, q, tc, tp)


# ---------------------------------------------------------------------------
# Cost model (Eq. 1 / Kaplan)
# ---------------------------------------------------------------------------


def test_kaplan_cost_model():
    cfg = configs.get("smollm-360m")
    cm = cost_model_from_config(cfg)
    # c_fwd = 2N + 2 n_layer n_ctx d_model
    n_ctx = 100
    expected = 2 * cfg.active_non_embedding_params() + 2 * cfg.num_layers * n_ctx * cfg.d_model
    assert cm.flops_per_token(n_ctx) == pytest.approx(expected)
    assert cm.query_cost(n_ctx, 10) == pytest.approx(10 * expected)


def test_moe_cost_uses_active_params():
    ds = configs.get("deepseek-v3-671b")
    assert ds.active_non_embedding_params() < 0.1 * ds.non_embedding_params()
    cm = cost_model_from_config(ds)
    assert cm.params_active == ds.active_non_embedding_params()


def test_pool_cost_matrix_shape_and_positivity():
    recs = generate_dataset(5, seed=0)
    costs = query_cost_matrix(DEFAULT_POOL, recs)
    assert costs.shape == (5, 8)
    assert (costs > 0).all()
    # 13B member costs more than 7B member on every query
    assert (costs[:, 1] > costs[:, 0]).all()


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------


def _toy():
    rng = np.random.default_rng(0)
    quality = jnp.asarray(rng.uniform(-4, -2, (8, 8)), jnp.float32)
    costs = jnp.asarray(rng.uniform(1e11, 5e12, (8, 8)), jnp.float32)
    return quality, costs


def test_policies_shapes_and_semantics():
    quality, costs = _toy()
    assert bool(jnp.all(FullEnsemblePolicy().select(quality, costs)))
    assert bool(jnp.all(RandomPolicy(k=3).select(quality, costs).sum(1) == 3))
    bs = BestSinglePolicy().select(quality, costs)
    assert bool(jnp.all(bs.sum(1) == 1))
    assert bool(jnp.all(jnp.argmax(quality, 1) == jnp.argmax(bs, 1)))
    hr = HybridRouterPolicy(small_index=0, large_index=1).select(quality, costs)
    assert bool(jnp.all(hr.sum(1) == 1))
    gr = GreedyRatioPolicy(EpsilonConstraint(0.2)).select(quality, costs)
    assert bool(jnp.all(realized_cost_fraction(gr, costs) <= 0.2 + 1e-6))


def test_modi_at_least_greedy():
    """Exact DP >= greedy ratio heuristic on shifted profit (always)."""
    quality, costs = _toy()
    profits, _ = shift_scores(quality)
    eps = EpsilonConstraint(0.25)
    m = ModiPolicy(eps).select(quality, costs)
    g = GreedyRatioPolicy(eps).select(quality, costs)
    vm = jnp.sum(jnp.where(m, profits, 0.0), 1)
    vg = jnp.sum(jnp.where(g, profits, 0.0), 1)
    assert bool(jnp.all(vm >= vg - 1e-4))


# ---------------------------------------------------------------------------
# Predictor (A.2)
# ---------------------------------------------------------------------------


def test_predictor_shapes_and_determinism():
    pred = build_predictor(num_models=8)
    p = pred.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 24), 0, 512)
    out1 = pred.apply(p, toks)
    out2 = pred.apply(p, toks)
    assert out1.shape == (4, 8)
    assert bool(jnp.all(out1 == out2))  # eval mode: no dropout


def test_predictor_learns_signal():
    """A few steps of Huber/Adam training reduces loss on a fixed batch."""
    pred = build_predictor(num_models=4)
    p = pred.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (16, 24), 0, 512)
    target = jax.random.normal(jax.random.key(2), (16, 4)) * 0.5 - 3.0
    batch = {"tokens": toks, "scores": target}
    from repro.optim import AdamW

    opt = AdamW(learning_rate=3e-4, b1=0.9, b2=0.98, weight_decay=0.01)
    state = opt.init(p)
    loss0 = float(pred.loss(p, batch)[0])

    @jax.jit
    def step(p, state):
        (l, _), g = jax.value_and_grad(pred.loss, has_aux=True)(p, batch)
        p, state = opt.update(g, state, p)
        return p, state, l

    for _ in range(30):
        p, state, l = step(p, state)
    assert float(pred.loss(p, batch)[0]) < loss0 * 0.9
