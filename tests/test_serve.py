"""Serving-engine integration tests (simulation pool; untrained or briefly
trained components — behaviourial invariants, not quality)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import EpsilonConstraint, FullEnsemblePolicy, ModiPolicy, build_predictor
from repro.data import DEFAULT_POOL, TOKENIZER, generate_dataset
from repro.models import build_model
from repro.serve import EnsembleServer, greedy_generate, greedy_generate_encdec
from repro.serve.generate import prompt_positions


@pytest.fixture(scope="module")
def stack():
    pred = build_predictor(num_models=len(DEFAULT_POOL))
    pp = pred.init(jax.random.key(0))
    fuser = build_model(configs.get("gen-fuser"))
    fp = fuser.init(jax.random.key(1))
    return pred, pp, fuser, fp


def test_serve_respects_budget_and_pipeline(stack):
    pred, pp, fuser, fp = stack
    srv = EnsembleServer(DEFAULT_POOL, ModiPolicy(EpsilonConstraint(0.2)), pred, pp, fuser, fp)
    recs = generate_dataset(6, seed=3)
    res = srv.serve(recs)
    assert res.mask.shape == (6, 8)
    assert (res.cost_fraction <= 0.2 + 1e-6).all()
    assert len(res.responses) == 6
    # member responses exist exactly where selected
    for i in range(6):
        for j in range(8):
            assert (res.member_responses[i][j] is not None) == bool(res.mask[i, j])
    assert srv.stats["queries"] == 6
    assert srv.stats["flops"] <= 0.2 * srv.stats["full_flops"] + 1e-6


def test_full_ensemble_costs_everything(stack):
    pred, pp, fuser, fp = stack
    srv = EnsembleServer(DEFAULT_POOL, FullEnsemblePolicy(), pred, pp, fuser, fp)
    res = srv.serve(generate_dataset(3, seed=4))
    assert bool(res.mask.all())
    assert np.allclose(res.cost_fraction, 1.0)


def test_prompt_positions_padding():
    toks = jnp.asarray([[5, 6, TOKENIZER.pad_id, TOKENIZER.pad_id], [1, 2, 3, 4]])
    pos, lengths = prompt_positions(toks, TOKENIZER.pad_id)
    assert pos.tolist() == [[0, 1, -1, -1], [0, 1, 2, 3]]
    assert lengths.tolist() == [2, 4]


def test_greedy_generate_stops_and_pads():
    cfg = configs.get("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompts = TOKENIZER.pad_batch([[TOKENIZER.bos_id, 65, 66], [TOKENIZER.bos_id, 67]], 8)
    out = greedy_generate(model, params, prompts, max_new=6)
    assert out.shape == (2, 6)
    assert out.dtype == np.int32


def test_generate_padded_equals_unpadded():
    """Right-padding a prompt must not change its generation."""
    cfg = configs.get("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = [TOKENIZER.bos_id, 72, 101, 108, 108, 111]
    a = greedy_generate(model, params, TOKENIZER.pad_batch([prompt], len(prompt)), max_new=5)
    b = greedy_generate(model, params, TOKENIZER.pad_batch([prompt], len(prompt) + 7), max_new=5)
    assert (a == b).all()


def test_encdec_generate():
    cfg = configs.get("gen-fuser")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    enc = TOKENIZER.pad_batch([TOKENIZER.encode("fuse this"), TOKENIZER.encode("and this")], 16)
    out = greedy_generate_encdec(model, params, enc, max_new=5)
    assert out.shape == (2, 5)
