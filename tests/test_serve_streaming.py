"""Streaming / token-level continuous batching suite (CI: the scenario
job runs it via ``-m "scenario or streaming"``).

Pins the acceptance properties of the persistent in-flight decode state:

* **offline equivalence** — rows decoded through the
  :class:`StreamingEncDecBatcher` (and through a streaming Scheduler end
  to end) are byte-identical to the batch-boundary path;
* **prefix stability** — every streamed :class:`StreamEvent` carries a
  token tuple that extends the previous event's and a text that is a
  string prefix of the final fused text;
* **mid-decode join** — requests submitted while earlier rows are still
  decoding join at the next step with zero new compiles once the rungs
  are warm, without perturbing co-resident rows;
* **sync/async byte-equivalence** — the ``streaming`` preset scenario
  produces identical traces, stats, and texts in both modes;

plus the fast-path bugfix regressions that ride along this PR:
``result(timeout=)`` racing its own resolution, ``_take_count`` clamping
to the ladder's top rung (no steady-state recompile when
``max_batch_size`` exceeds it), and ``padded_rows`` counted once per
served dispatch even when the batch pays a hedged retry.
"""

import threading

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import build_predictor, make_policy
from repro.data import DEFAULT_POOL, TOKENIZER, generate_dataset
from repro.models import build_model
from repro.serve import (
    BucketLadder,
    EnsembleServer,
    FailureInjector,
    Scheduler,
    StreamingEncDecBatcher,
    TrafficSimulator,
    greedy_generate_encdec,
    preset_scenarios,
    requests_from_records,
)

pytestmark = pytest.mark.streaming


@pytest.fixture(scope="module")
def fuser():
    model = build_model(configs.get("gen-fuser"))
    return model, model.init(jax.random.key(1))


@pytest.fixture(scope="module")
def stack():
    pred = build_predictor(num_models=len(DEFAULT_POOL))
    pp = pred.init(jax.random.key(0))
    fuser = build_model(configs.get("gen-fuser"))
    fp = fuser.init(jax.random.key(1))
    return pred, pp, fuser, fp


def _server(stack, policy="modi", **kwargs):
    pred, pp, fuser, fp = stack
    return EnsembleServer(DEFAULT_POOL, make_policy(policy, **kwargs),
                          pred, pp, fuser, fp)


RECORDS = generate_dataset(12, seed=3)
LADDER = BucketLadder(batch=(1, 2, 4), new_tokens=(8, 16), prompt=(32,))


def _enc(texts, enc_seq=32):
    return TOKENIZER.pad_batch([TOKENIZER.encode(t) for t in texts], enc_seq)


def _assert_row_matches_direct(tokens, direct_row):
    """A streamed row equals the batch-boundary reference: the emitted
    tokens are the reference's leading tokens, anything the eviction
    skipped is trailing pad, and the decoded text is identical."""
    tokens = list(tokens)
    np.testing.assert_array_equal(np.asarray(tokens),
                                  np.asarray(direct_row[:len(tokens)]))
    assert (np.asarray(direct_row[len(tokens):]) == TOKENIZER.pad_id).all()
    assert TOKENIZER.decode(tokens) == TOKENIZER.decode(list(direct_row))


# ---------------------------------------------------------------------------
# Batcher: offline equivalence + token-order / prefix monotonicity
# ---------------------------------------------------------------------------


def test_batcher_matches_offline_greedy(fuser):
    model, params = fuser
    batcher = StreamingEncDecBatcher(model, params, enc_seq=32, capacity=4,
                                     ladder=LADDER)
    enc = _enc(["fuse this", "and this", "third row", "fourth entry"])
    done, snaps = {}, {i: [] for i in range(4)}
    batcher.submit(
        enc, [8, 8, 8, 8],
        on_token=lambda i, toks: snaps[i].append(tuple(toks)),
        on_done=lambda i, toks: done.__setitem__(i, list(toks)))
    batcher.pump()
    assert batcher.idle and sorted(done) == [0, 1, 2, 3]
    direct = np.asarray(greedy_generate_encdec(model, params, enc, max_new=8))
    for i in range(4):
        _assert_row_matches_direct(done[i], direct[i])
        # token-order property: each emission extends the previous one,
        # and the last snapshot is exactly the settled row
        for a, b in zip(snaps[i], snaps[i][1:]):
            assert b[:len(a)] == a
        assert snaps[i][-1] == tuple(done[i])
    # one rung in play: prefill + join + the capacity-shaped step
    assert batcher.compiles == 3
    assert batcher.stats["evicted"] == 4


def test_batcher_mid_decode_join_zero_recompiles(fuser):
    """Join/leave mid-decode golden trace: a second wave submitted while
    the first is mid-decode joins at the next step with zero new compiles,
    and neither wave's bytes depend on the co-resident rows."""
    model, params = fuser
    batcher = StreamingEncDecBatcher(model, params, enc_seq=32, capacity=4,
                                     ladder=LADDER)
    batcher.warm([2])
    warm_compiles = batcher.compiles
    assert warm_compiles == 3  # prefill(2) + join(2) + step

    enc_a = _enc(["first wave row", "second row here"])
    enc_b = _enc(["late arrival one", "late two"])
    done, trace = {}, []

    def _on_done(off):
        return lambda i, toks: (done.__setitem__(off + i, list(toks)),
                                trace.append(("done", off + i)))

    batcher.submit(enc_a, [8, 8], on_done=_on_done(0))
    mid = batcher.pump(steps=3)
    assert mid == 3 and batcher.in_flight == 2
    snap_a = {i: list(done.get(i, [])) for i in range(2)}
    batcher.submit(enc_b, [8, 8], on_done=_on_done(2))  # join mid-decode
    assert batcher.in_flight == 4  # admitted into the free slots
    batcher.pump()

    assert batcher.compiles == warm_compiles  # THE acceptance gate: 0 new
    assert batcher.idle and sorted(done) == [0, 1, 2, 3]
    assert batcher.stats["joins"] == 2 and batcher.stats["evicted"] == 4
    # first wave completes before the late wave (equal caps, 3-step lead):
    # the golden eviction order is deterministic
    assert trace == [("done", 0), ("done", 1), ("done", 2), ("done", 3)]
    assert not snap_a[0] and not snap_a[1]  # still in flight at the join

    direct_a = np.asarray(greedy_generate_encdec(model, params, enc_a, max_new=8))
    direct_b = np.asarray(greedy_generate_encdec(model, params, enc_b, max_new=8))
    for i in range(2):
        _assert_row_matches_direct(done[i], direct_a[i])
        _assert_row_matches_direct(done[2 + i], direct_b[i])


# ---------------------------------------------------------------------------
# Scheduler end-to-end: streamed prefixes ⊂ final fused text, byte equality
# ---------------------------------------------------------------------------


def test_stream_prefix_stability_and_final_equality(stack):
    server = _server(stack, budget=0.2)
    sched = Scheduler(server, max_batch_size=4, stream=True, stream_capacity=4)
    reqs = requests_from_records(RECORDS[:4])
    futs = [sched.submit(r) for r in reqs]
    baseline = _server(stack, budget=0.2).serve_requests(reqs)
    for f, base in zip(futs, baseline):
        events = list(f.stream())
        assert events and events[-1].final
        final = events[-1].response
        assert final is not None and final.text == base.text
        assert (final.mask == base.mask).all()
        assert final.realized_cost == base.realized_cost
        prev = ()
        for ev in events[:-1]:
            assert not ev.final and ev.response is None
            assert ev.tokens[:len(prev)] == prev  # monotone token growth
            prev = ev.tokens
            # streamed text is a *string* prefix of the final fused text
            # (decode_capped strips trailing incomplete UTF-8)
            assert final.text.startswith(ev.text)
        assert f.ttft_s is not None and f.ttft_s >= 0.0
    assert sched.stats["stream_tokens"] > 0


def test_streaming_preset_sync_async_byte_equivalence(stack):
    """The ``streaming`` preset in both scheduler modes: identical trace,
    stats (incl. stream_tokens), texts, and latencies — and both equal the
    offline non-streaming batch path."""
    scenario = preset_scenarios(n_requests=12)["streaming"]
    assert scenario.streaming  # the preset actually exercises the path
    sync_rep = TrafficSimulator(
        Scheduler(_server(stack, budget=0.2), max_batch_size=4,
                  max_wait_ticks=2), scenario, RECORDS).run()
    sched = Scheduler(_server(stack, budget=0.2), max_batch_size=4,
                      max_wait_ticks=2, sync=False)
    try:
        async_rep = TrafficSimulator(sched, scenario, RECORDS).run()
    finally:
        sched.close()
    assert async_rep.trace == sync_rep.trace
    assert async_rep.stats == sync_rep.stats
    assert sync_rep.stats["stream_tokens"] > 0
    assert ([r.text if r else None for r in async_rep.responses]
            == [r.text if r else None for r in sync_rep.responses])
    assert async_rep.latency_ticks == sync_rep.latency_ticks

    assert sync_rep.served == sync_rep.n
    offline = _server(stack, budget=0.2).serve_requests(sync_rep.requests)
    assert ([r.text for r in sync_rep.responses] == [r.text for r in offline])


# ---------------------------------------------------------------------------
# Bugfix regressions riding along this PR
# ---------------------------------------------------------------------------


class _ExpiredWait:
    """Event stand-in whose wait() always reports expiry — the future's
    batch resolves (sync dispatch inside result()) while the wait claims
    to have timed out, which is exactly the race being pinned."""

    def __init__(self):
        self._flag = False

    def set(self):
        self._flag = True

    def is_set(self):
        return self._flag

    def wait(self, timeout=None):
        return False


def test_result_timeout_race_with_own_resolution(stack):
    """result(timeout=) whose wait expires concurrently with the batch
    landing must return the response, not raise — and must not spuriously
    bump result_timeouts or write a timeout trace event."""
    sched = Scheduler(_server(stack, budget=0.2), max_batch_size=4)
    fut = sched.submit(requests_from_records(RECORDS[:1])[0])
    fut._resolved = _ExpiredWait()
    resp = fut.result(timeout=0.001)
    assert resp.text == _server(stack, budget=0.2).serve_requests(
        requests_from_records(RECORDS[:1]))[0].text
    assert sched.stats["result_timeouts"] == 0
    assert not any(e.get("event") == "timeout"
                   for e in sched.events if isinstance(e, dict))


def test_result_timeout_still_raises_when_unresolved(stack):
    """The legitimate-timeout side of the race fix: an actually-unserved
    future still raises, records the abandoned wait, and stays resolvable
    once the batch lands."""
    sched = Scheduler(_server(stack, budget=0.2), max_batch_size=2,
                      sync=False)
    try:
        blocker = threading.Event()
        inner = sched.server.backend
        orig = inner.generate

        def slow_generate(j, records, caps):
            blocker.wait(10.0)
            return orig(j, records, caps)

        inner.generate = slow_generate
        futs = [sched.submit(r) for r in requests_from_records(RECORDS[:2])]
        with pytest.raises(TimeoutError):
            futs[0].result(timeout=0.05)
        assert sched.stats["result_timeouts"] == 1
        blocker.set()
        assert futs[0].result(timeout=10.0).text  # later call resolves
    finally:
        sched.close()


def test_take_count_clamps_to_top_ladder_rung(stack):
    """max_batch_size above the ladder's top rung must never produce a
    batch beyond that rung (each one would compile a brand-new bucket in
    steady state); the remainder dispatches as a follow-on batch."""
    lad = BucketLadder(batch=(1, 2, 4))
    server = _server(stack, budget=0.2)
    sched = Scheduler(server, max_batch_size=8, ladder=lad)
    assert sched._take_count(8, 8) == 4  # forced past the top rung: clamped
    assert sched._take_count(8, 0) == 4
    assert sched._take_count(5, 2) == 4
    assert sched._take_count(3, 3) == 3  # padded up to the enclosing rung

    server.warm([(2, server.max_new_tokens), (4, server.max_new_tokens)])
    c0 = server.generate_compiles()["total"]
    reqs = requests_from_records(generate_dataset(6, seed=7))
    futs = [sched.submit(r) for r in reqs]
    sched.flush()  # forces all 6: clamp -> batch of 4 + follow-on of 2
    texts = [f.result().text for f in futs]
    assert sched.stats["dispatched_batches"] == 2
    assert sched.stats["dispatched_requests"] == 6
    assert server.generate_compiles()["total"] == c0  # zero new compiles
    offline = _server(stack, budget=0.2).serve_requests(reqs)
    assert texts == [r.text for r in offline]


def test_hedged_retry_counts_padding_once(stack):
    """padded_rows is charged once per *served* dispatch: a batch that
    pays a hedged retry must not double-count its padding."""
    reqs = requests_from_records(RECORDS[:3])
    probe = _server(stack, budget=0.2).serve_requests(reqs)
    member = int(np.flatnonzero(probe[0].mask)[0])  # guaranteed selected
    server = _server(stack, budget=0.2)
    server.backend = FailureInjector(server.backend, failures={member: (0,)})
    sched = Scheduler(server, max_batch_size=4)
    futs = [sched.submit(r) for r in reqs]
    sched.flush()
    for f in futs:
        f.result()
    assert sched.stats["hedges"] == 1  # the injection fired
    assert sched.stats["dispatched_batches"] == 1
    # 3 rows -> rung 4: one padding row, counted once — not once per attempt
    assert sched.stats["padded_rows"] == 1
