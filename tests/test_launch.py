"""Launch-layer tests: plans build and lower on a 1x1(x1) host mesh.

(The real 256/512-device dry-run is exercised by repro.launch.dryrun; these
tests validate the plan machinery inside pytest without forcing devices.)
"""

import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro import configs
from repro.launch.shapes import (
    INPUT_SHAPES,
    InputShape,
    adapt_config,
    microbatches_for,
    shape_skip_reason,
)
from repro.launch.steps import build_plan
from repro.sharding.api import axis_rules, default_axis_rules

TINY_TRAIN = InputShape("train_tiny", 64, 8, "train")
TINY_PREFILL = InputShape("prefill_tiny", 64, 4, "prefill")
TINY_DECODE = InputShape("decode_tiny", 64, 4, "decode")


@pytest.fixture(scope="module")
def rules():
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    return default_axis_rules(mesh)


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-370m", "whisper-base", "deepseek-v3-671b"])
@pytest.mark.parametrize("shape", [TINY_TRAIN, TINY_PREFILL, TINY_DECODE])
def test_plan_lowers_reduced(arch, shape, rules):
    cfg = configs.get(arch).reduced(dtype="float32")
    with axis_rules(rules):
        plan = build_plan(arch, cfg, shape, rules)
        lowered = jax.jit(plan.step_fn).lower(*plan.args_sds)
        assert lowered is not None
        compiled = lowered.compile()
        assert compiled.cost_analysis() is not None


def test_shape_table_matches_spec():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)


def test_long_context_adaptation():
    dense = configs.get("qwen2.5-32b")
    adapted = adapt_config(dense, INPUT_SHAPES["long_500k"])
    assert adapted.sliding_window == 8192
    ssm = configs.get("mamba2-370m")
    assert adapt_config(ssm, INPUT_SHAPES["long_500k"]).sliding_window == 0
    assert shape_skip_reason(configs.get("whisper-base"), INPUT_SHAPES["long_500k"])
    assert shape_skip_reason(dense, INPUT_SHAPES["long_500k"]) is None


def test_microbatches_respect_data_shards():
    assert microbatches_for("deepseek-v3-671b", 16, 256) == 16
    assert microbatches_for("deepseek-v3-671b", 32, 256) == 8
    assert microbatches_for("smollm-360m", 1, 8) == 4


def test_collective_parser():
    from repro.launch.dryrun import parse_collective_bytes

    hlo = """
      %ag = bf16[16,128]{1,0} all-gather(bf16[1,128] %x), dims={0}
      %ar = (f32[4,4]{1,0}, f32[2]{0}) all-reduce(f32[4,4] %a, f32[2] %b)
      %nothing = f32[8] add(f32[8] %p, f32[8] %q)
    """
    by, counts = parse_collective_bytes(hlo)
    assert by["all-gather"] == 16 * 128 * 2
    assert by["all-reduce"] == 4 * 4 * 4 + 2 * 4
    assert counts["all-gather"] == 1 and counts["all-reduce"] == 1
