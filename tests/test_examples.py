"""Smoke: the runnable examples execute end-to-end."""

import subprocess
import sys
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run(script):
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", script)],
        env=ENV, capture_output=True, text=True, timeout=900, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_quickstart():
    stdout = _run("quickstart.py")
    assert "llm-blender" in stdout
    assert "eps= 0.2" in stdout


def test_pareto_sweep():
    stdout = _run("pareto_sweep.py")
    assert "brute-force frontier" in stdout
    assert "eps-sweep frontier" in stdout
