"""Scenario suite for the serving stack (its own CI tier: ``-m scenario``).

Drives the continuous-batching Scheduler through the deterministic
traffic simulator and pins three properties per scenario:

* **offline equivalence** — the simulated stream's fused responses are
  byte-identical to one offline ``EnsembleServer.serve_requests`` call
  over the same requests (and, for override-free scenarios, to
  ``EnsembleServer.serve`` over the same records);
* **golden counters** — deadline-miss and shed counts match hand-computed
  traces on small scenarios whose schedules can be worked out on paper;
* **replayability** — re-running a scenario from scratch reproduces the
  event trace byte for byte.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import build_predictor, make_policy
from repro.data import DEFAULT_POOL, generate_dataset
from repro.models import build_model
from repro.serve import (
    AdmissionControl,
    ArrivalProcess,
    EnsembleRequest,
    EnsembleServer,
    RequestShed,
    Scenario,
    Scheduler,
    TrafficSimulator,
    preset_scenarios,
)

pytestmark = pytest.mark.scenario


@pytest.fixture(scope="module")
def stack():
    pred = build_predictor(num_models=len(DEFAULT_POOL))
    pp = pred.init(jax.random.key(0))
    fuser = build_model(configs.get("gen-fuser"))
    fp = fuser.init(jax.random.key(1))
    return pred, pp, fuser, fp


def _server(stack, policy="modi", **kwargs):
    pred, pp, fuser, fp = stack
    return EnsembleServer(DEFAULT_POOL, make_policy(policy, **kwargs),
                          pred, pp, fuser, fp)


RECORDS = generate_dataset(12, seed=3)


# ---------------------------------------------------------------------------
# Offline equivalence: any batching/deadline/priority schedule, same bytes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["steady", "bursty", "heavy-tail"])
def test_scenario_stream_matches_offline_batch(stack, name):
    scenario = preset_scenarios(n_requests=12)[name]
    sched = Scheduler(_server(stack, budget=0.2), max_batch_size=4,
                      max_wait_ticks=2)
    report = TrafficSimulator(sched, scenario, RECORDS).run()
    assert report.served == report.n  # nothing shed, nothing hung
    offline = _server(stack, budget=0.2).serve_requests(report.requests)
    assert [r.text for r in report.responses] == [r.text for r in offline]
    assert all((a.mask == b.mask).all()
               for a, b in zip(report.responses, offline))


def test_override_free_scenario_matches_serve_records(stack):
    """steady has no mix, so its requests are bare record wraps — the
    stream must also equal the plain offline ``serve`` over the records."""
    scenario = preset_scenarios(n_requests=12)["steady"]
    sched = Scheduler(_server(stack, budget=0.2), max_batch_size=4,
                      max_wait_ticks=2)
    report = TrafficSimulator(sched, scenario, RECORDS).run()
    offline = _server(stack, budget=0.2).serve(RECORDS)
    assert [r.text for r in report.responses] == offline.responses


def test_failure_scenario_hedges_and_stays_equivalent(stack):
    """Injected member failure: the batch re-serves on the survivors, every
    future resolves, and responses equal the offline path — plain for
    untouched requests, member-excluded for the hedged batch."""
    scenario = preset_scenarios(n_requests=12)["failure"]
    sched = Scheduler(_server(stack, budget=0.2), max_batch_size=4,
                      max_wait_ticks=2)
    report = TrafficSimulator(sched, scenario, RECORDS).run()
    assert report.served == report.n  # no hung or failed futures
    assert report.stats["hedges"] >= 1

    hedged, excluded = set(), set()
    for ev in report.trace:
        if ev["event"] == "hedge":
            hedged.update(ev["reqs"])
            excluded.update(ev["exclude"])
    assert hedged and excluded  # the injection actually fired

    plain = _server(stack, budget=0.2).serve_requests(report.requests)
    for i in range(report.n):
        if i not in hedged:
            assert report.responses[i].text == plain[i].text
    aff = sorted(hedged)
    retried = _server(stack, budget=0.2).serve_requests(
        [report.requests[i] for i in aff],
        exclude_members=frozenset(excluded))
    for i, resp in zip(aff, retried):
        assert report.responses[i].text == resp.text
        assert not report.responses[i].mask[sorted(excluded)].any()


def test_failure_scenario_on_reused_server_rewraps_injector(stack):
    """A second failure-scenario run against the same server must reinstall
    a fresh injection schedule with reset call counters (regression: an
    idempotent wrap kept the first run's consumed counters, silently
    turning the second run's faults into no-ops)."""
    scenario = preset_scenarios(n_requests=12)["failure"]
    server = _server(stack, budget=0.2)
    r1 = TrafficSimulator(Scheduler(server, max_batch_size=4, max_wait_ticks=2),
                          scenario, RECORDS).run()
    r2 = TrafficSimulator(Scheduler(server, max_batch_size=4, max_wait_ticks=2),
                          scenario, RECORDS).run()
    assert r1.stats["hedges"] == r2.stats["hedges"] == 1
    assert r1.trace == r2.trace  # replay guarantee holds across reuse


def test_hedging_disabled_fails_batch_but_resolves_futures(stack):
    scenario = preset_scenarios(n_requests=12)["failure"]
    sched = Scheduler(_server(stack, budget=0.2), max_batch_size=4,
                      max_wait_ticks=2, hedge=False)
    report = TrafficSimulator(sched, scenario, RECORDS).run()
    failed = [e for e in report.errors if e is not None]
    assert failed  # the injected fault surfaced
    # but every future resolved one way or the other — none left pending
    assert report.served + len(failed) == report.n


# ---------------------------------------------------------------------------
# Golden traces: hand-computed deadline-miss / shed counters
# ---------------------------------------------------------------------------


def test_deadline_miss_golden_trace(stack):
    """5 same-policy requests arrive at tick 0 (max_batch_size=8, so no
    inline dispatch; max_wait_ticks=10, so age never triggers):

    * 2 with deadline_ticks=0 (absolute deadline 0),
    * 3 with deadline_ticks=3 (absolute deadline 3).

    tick 1: the two deadline-0 requests are due (0 <= 1).  EDF puts them
    first; 5 candidates is not a ladder rung, the floor rung is 4 and
    2 are forced, so the batch takes 4: both deadline-0 (served at tick
    1 > 0 — two misses) plus two deadline-3 rides-along (met).  tick 2:
    nothing due.  tick 3: the last deadline-3 request is due and served
    exactly at its deadline — met.  Totals: 2 misses, 2 batches of
    sizes 4 and 1, zero padded rows (both sizes are rungs)."""
    sched = Scheduler(_server(stack, budget=0.2), max_batch_size=8,
                      max_wait_ticks=10)
    recs = generate_dataset(5, seed=7)
    futures = []
    for i, rec in enumerate(recs):
        futures.append(sched.submit(EnsembleRequest(
            query=rec.query, record=rec,
            deadline_ticks=0 if i < 2 else 3)))
    assert sched.pending == 5
    assert sched.tick() == 4  # forced pair + two rides-along
    assert [f.done() for f in futures] == [True, True, True, True, False]
    assert sched.tick() == 0  # tick 2: nothing due
    assert sched.tick() == 1  # tick 3: last request at its deadline
    assert sched.stats["deadline_misses"] == 2
    assert [f.deadline_missed for f in futures] == [True, True, False, False, False]
    assert sched.stats["dispatched_batches"] == 2
    assert sched.stats["padded_rows"] == 0  # 4 and 1 are both ladder rungs
    miss_events = [e for e in sched.events if e["event"] == "miss"]
    assert sorted(e["req"] for e in miss_events) == [0, 1]


def test_shed_golden_trace(stack):
    """llm-blender at full cost with shed threshold 0.9 over a 4-tick
    window, max_batch_size=2: submits 1-2 fill a batch (window empty, so
    admitted) and dispatch inline at tick 0 at cost fraction 1.0; submits
    3-6 all see the window at 1.0 >= 0.9 and shed.  After the window
    slides past tick 0 (4 ticks later) traffic admits again."""
    sched = Scheduler(
        _server(stack, policy="llm-blender"), max_batch_size=2,
        max_wait_ticks=10,
        admission=AdmissionControl(window_ticks=4, shed_fraction=0.9))
    recs = generate_dataset(7, seed=5)
    futures = [sched.submit(EnsembleRequest(query=r.query, record=r))
               for r in recs[:6]]
    assert sched.stats["shed"] == 4
    assert [f.shed() for f in futures] == [False, False, True, True, True, True]
    for f in futures[2:]:
        with pytest.raises(RequestShed):
            f.result()
    for _ in range(5):
        sched.tick()
    late = sched.submit(EnsembleRequest(query=recs[6].query, record=recs[6]))
    assert not late.shed()  # the hot window has rolled off
    assert sched.stats["shed"] == 4


def test_downgrade_golden_trace(stack):
    """modi at ε=1.0 (selects nearly everything) with a 0.5 downgrade
    threshold: the first inline batch fills the window at ~1.0, so the
    following submits are downgraded to ε=0.1 and their realized cost
    fraction obeys the tightened budget."""
    sched = Scheduler(
        _server(stack, budget=1.0), max_batch_size=2, max_wait_ticks=10,
        admission=AdmissionControl(window_ticks=4, downgrade_fraction=0.5,
                                   downgrade_budget=0.1))
    recs = generate_dataset(4, seed=9)
    futures = [sched.submit(EnsembleRequest(query=r.query, record=r))
               for r in recs]
    sched.flush()
    assert sched.stats["downgraded"] == 2
    assert [f.result().cost_fraction <= 0.1 + 1e-6 for f in futures] == [
        False, False, True, True]


# ---------------------------------------------------------------------------
# Determinism: the trace is replayable byte for byte
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["bursty", "failure"])
def test_scenario_trace_replays_identically(stack, name):
    scenario = preset_scenarios(n_requests=12)[name]

    def run_once():
        sched = Scheduler(_server(stack, budget=0.2), max_batch_size=4,
                          max_wait_ticks=2,
                          admission=AdmissionControl(window_ticks=4))
        return TrafficSimulator(sched, scenario, RECORDS).run()

    a, b = run_once(), run_once()
    assert a.trace == b.trace  # ticks, batches, digests — everything
    assert a.stats == b.stats
    assert a.latency_ticks == b.latency_ticks


def test_arrival_processes_are_deterministic_and_ordered():
    rng = np.random.default_rng(0)
    for kind in ("steady", "bursty", "heavy-tail"):
        proc = ArrivalProcess(kind)
        a = proc.arrival_ticks(20, np.random.default_rng(5))
        b = proc.arrival_ticks(20, np.random.default_rng(5))
        assert a == b
        assert all(x <= y for x, y in zip(a, a[1:]))  # non-decreasing
    with pytest.raises(ValueError):
        ArrivalProcess("poissonish").arrival_ticks(3, rng)


def test_priority_orders_same_deadline_requests(stack):
    """Two requests, same deadline, one high priority: EDF tie-break puts
    the high-priority request in the first (rung-snapped) batch."""
    sched = Scheduler(_server(stack, budget=0.2), max_batch_size=8,
                      max_wait_ticks=10)
    recs = generate_dataset(3, seed=13)
    futs = [sched.submit(EnsembleRequest(query=r.query, record=r,
                                         deadline_ticks=1,
                                         priority=(3 if i == 2 else 0)))
            for i, r in enumerate(recs)]
    sched.tick()  # all due; 3 is not a rung -> floor rung 2, forced... all 3
    # all three were due, so all are forced out regardless of rung snapping
    assert all(f.done() for f in futs)
    first_batch = next(e for e in sched.events if e["event"] == "dispatch")
    assert first_batch["reqs"][0] == 2  # high priority leads the batch
