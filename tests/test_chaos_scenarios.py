"""Chaos-scenario tier: concurrent fan-out, host recovery, rebalance.

Runs as its own CI job (``pytest -m chaos``) under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — and, like every
cluster test, passes identically on one device (logical-only placement).

What this tier pins, beyond the happy paths of the scenario/cluster
suites:

* **fan-out byte-equivalence** — ``ClusterRouter(fanout=True)`` (per-host
  shards on concurrent ``HostExecutor`` threads) produces traces, stats,
  and responses byte-identical to sequential routing on EVERY preset
  scenario: fan-out may change wall-clock, never outputs;
* **rolling host outages** — two hosts dying at different points in one
  run: the knapsack re-solve masks exactly the newly dead members each
  time (golden trace), and every future still resolves;
* **revival mid-burst** — outage → probation → revival inside a bursty
  arrival stream: the revive event lands at its deterministic tick and
  post-revival batches stop pre-masking the recovered members;
* **replica-loss-then-rebalance** — a host death absorbed by replica
  failover leaves members under-replicated; tick-driven maintenance
  re-places them so ANY single further host death strands nobody;
* **random chaos property** — for random placements, failure schedules,
  and probation windows, fan-out + recovery serves exactly the requests
  the sequential reference serves, and no dispatch ever routes to a host
  that was dead at dispatch time (router audit log);
* **hardening regressions under fan-out** — the PR 4 closed-worker
  future resolution and total-outage "no servable pool members" paths
  survive ``fanout=True``;
* **pre-mask snapshot stability** — the per-batch dead-member snapshot
  (taken at dispatch time on the serving thread) keeps async traces
  byte-identical to sync even when a death lands while later batches
  are already queued;
* **probe-driven health** — the ``probe-recovery`` golden trace (a
  half-open probe revives the dispatch-observed death strictly earlier
  than the schedule+probation path), a crash-on-probe kill that strands
  members *without* any dispatch ever exploding, and the exponential
  half-open backoff window;
* **grey failures** — the ``grey-failure`` straggler hedge is
  byte-invisible (sequential == fan-out, outputs == offline), and a
  *wall-clock* straggler host under ``shard_deadline_s`` is cancelled
  and hedged onto a replica with baseline bytes;
* **graceful degradation** — ``allow_degraded=True`` serves partial
  ensembles through an outage with hedging off: knapsack re-solved over
  survivors, responses tagged with the missing members, and the
  ``degraded`` settlement events matching a hand-computed
  survivor-cost sum.
"""

import dataclasses
import time

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs
from repro.core import build_predictor, make_policy
from repro.data import DEFAULT_POOL, generate_dataset, query_cost_matrix
from repro.models import build_model
from repro.serve import (
    ArrivalProcess,
    ClusterRouter,
    EnsembleRequest,
    EnsembleServer,
    HostFailure,
    PlacementPlan,
    Scenario,
    Scheduler,
    TrafficSimulator,
    current_dispatch_host,
    preset_scenarios,
    requests_from_records,
)

pytestmark = [pytest.mark.chaos]

N_POOL = len(DEFAULT_POOL)
RECORDS = generate_dataset(24, seed=3)


@pytest.fixture(scope="module")
def stack():
    pred = build_predictor(num_models=N_POOL)
    pp = pred.init(jax.random.key(0))
    fuser = build_model(configs.get("gen-fuser"))
    fp = fuser.init(jax.random.key(1))
    return pred, pp, fuser, fp


def _server(stack, policy="modi", **kwargs):
    pred, pp, fuser, fp = stack
    return EnsembleServer(DEFAULT_POOL, make_policy(policy, **kwargs),
                          pred, pp, fuser, fp)


def _sched(stack, sync=True, policy="modi", **kwargs):
    kwargs.setdefault("max_batch_size", 4)
    kwargs.setdefault("max_wait_ticks", 2)
    policy_kwargs = {"budget": 0.2} if policy == "modi" else {}
    return Scheduler(_server(stack, policy=policy, **policy_kwargs),
                     sync=sync, **kwargs)


def _run(sched, scenario, records=RECORDS):
    try:
        return TrafficSimulator(sched, scenario, records).run()
    finally:
        backend = sched.server.backend
        if isinstance(backend, ClusterRouter):
            backend.close()
        sched.close()


def _texts(report):
    return [r.text if r is not None else None for r in report.responses]


# ---------------------------------------------------------------------------
# Fan-out byte-equivalence on every preset scenario
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(preset_scenarios()))
def test_fanout_matches_sequential_on_every_preset(stack, name):
    """fanout=True must be invisible in the trace: same events, same
    stats, same bytes — on every preset, including those that only grow
    a cluster router for this comparison."""
    base = preset_scenarios(n_requests=12)[name]
    seq = dataclasses.replace(base, hosts=base.hosts or 4, fanout=False)
    fan = dataclasses.replace(base, hosts=base.hosts or 4, fanout=True)
    seq_rep = _run(_sched(stack), seq)
    fan_rep = _run(_sched(stack), fan)
    assert fan_rep.trace == seq_rep.trace
    assert fan_rep.stats == seq_rep.stats
    assert _texts(fan_rep) == _texts(seq_rep)
    assert fan_rep.latency_ticks == seq_rep.latency_ticks


def test_fanout_actually_fans_out(stack):
    """Sanity for the comparison above: the fan-out run really did run
    per-host shards through the executor pool (not the sequential path)."""
    scenario = dataclasses.replace(
        preset_scenarios(n_requests=12)["steady"], hosts=4, fanout=True)
    sched = _sched(stack)
    report = _run(sched, scenario)
    router = sched.server.backend
    assert isinstance(router, ClusterRouter)
    assert router.stats["fanout_batches"] > 0
    assert router.stats["shards"] >= router.stats["fanout_batches"]
    assert report.served == report.n


# ---------------------------------------------------------------------------
# Rolling host outages (golden trace)
# ---------------------------------------------------------------------------

ROLLING = Scenario(
    name="rolling-outage",
    arrivals=ArrivalProcess("steady", rate=2.0),
    n_requests=16, seed=0, deadline_ticks=4, hosts=4,
    host_failures=((0, (1,)), (2, (3,))),
)


def test_rolling_outages_golden_trace(stack):
    """Two hosts die at different points; each hedge masks exactly the
    newly dead members, the mask accumulates, every future resolves.
    The golden events are hand-derived from the deterministic placement
    (auto over 4 hosts: host 0 holds members [1, 7], host 2 holds
    [3, 4]) and the injected dispatch schedule."""
    report = _run(_sched(stack), ROLLING)
    assert report.served == report.n == 16
    assert report.stats["host_hedges"] == 2

    structural = [e for e in report.trace
                  if e["event"] in ("host_hedge", "dispatch")]
    assert structural == [
        {"tick": 1, "event": "dispatch", "reqs": [0, 1, 2, 3], "size": 4,
         "bucket": 4, "exclude": [], "masked": []},
        {"tick": 3, "event": "host_hedge", "host": 0, "members": [1, 7],
         "reqs": [4, 5, 6, 7], "masked": [1, 7]},
        {"tick": 3, "event": "dispatch", "reqs": [4, 5, 6, 7], "size": 4,
         "bucket": 4, "exclude": [], "masked": [1, 7]},
        {"tick": 5, "event": "host_hedge", "host": 2, "members": [3, 4],
         "reqs": [8, 9, 10, 11], "masked": [1, 3, 4, 7]},
        {"tick": 5, "event": "dispatch", "reqs": [8, 9, 10, 11], "size": 4,
         "bucket": 4, "exclude": [], "masked": [1, 3, 4, 7]},
        {"tick": 7, "event": "dispatch", "reqs": [12, 13, 14, 15], "size": 4,
         "bucket": 4, "exclude": [], "masked": [1, 3, 4, 7]},
    ]
    # post-outage responses never select a dead member
    for i in range(4, 16):
        assert not report.responses[i].mask[[1, 7]].any()
    for i in range(8, 16):
        assert not report.responses[i].mask[[1, 3, 4, 7]].any()


def test_rolling_outages_fanout_equivalent_and_replayable(stack):
    fan = dataclasses.replace(ROLLING, fanout=True)
    a = _run(_sched(stack), fan)
    b = _run(_sched(stack), fan)
    seq = _run(_sched(stack), ROLLING)
    assert a.trace == b.trace == seq.trace
    assert _texts(a) == _texts(b) == _texts(seq)


# ---------------------------------------------------------------------------
# Revival mid-burst (golden trace)
# ---------------------------------------------------------------------------

BURST_REVIVE = Scenario(
    name="burst-revive",
    arrivals=ArrivalProcess("bursty", burst_size=6, burst_every=4),
    n_requests=18, seed=0, deadline_ticks=6, hosts=4,
    host_failures=((0, (1,)),),
    host_recoveries=((0, (5,)),), probation_ticks=2,
)


def test_revival_mid_burst_golden_trace(stack):
    """Outage at tick 2 (members [1, 7] stranded), recovery declared at
    tick 5, probation 2 → revive at tick 7, mid-stream: batches before
    the revival pre-mask [1, 7], batches after select them again."""
    report = _run(_sched(stack), BURST_REVIVE)
    assert report.served == report.n == 18

    revives = [e for e in report.trace if e["event"] == "revive"]
    assert revives == [{"tick": 7, "event": "revive", "host": 0,
                        "recovered": [1, 7], "probation": 2}]
    masked_by_tick = [(e["tick"], e["masked"]) for e in report.trace
                      if e["event"] == "dispatch"]
    assert masked_by_tick == [
        (0, []), (2, [1, 7]), (4, [1, 7]), (6, [1, 7]), (8, []), (10, []),
    ]
    # the revived members are selectable again: post-revival responses
    # equal the plain offline path (no masking at all)
    post = [i for i in range(12, 18)]
    offline = _server(stack, budget=0.2).serve_requests(
        [report.requests[i] for i in post])
    assert [report.responses[i].text for i in post] == [r.text for r in offline]


def test_revival_mid_burst_fanout_and_async_equivalent(stack):
    sync_rep = _run(_sched(stack), BURST_REVIVE)
    async_rep = _run(_sched(stack, sync=False), BURST_REVIVE)
    fan_rep = _run(_sched(stack), dataclasses.replace(BURST_REVIVE, fanout=True))
    assert async_rep.trace == sync_rep.trace
    assert fan_rep.trace == sync_rep.trace
    assert _texts(async_rep) == _texts(sync_rep) == _texts(fan_rep)


# ---------------------------------------------------------------------------
# Replica loss, then rebalance
# ---------------------------------------------------------------------------


def test_replica_loss_then_rebalance_restores_redundancy(stack):
    """replicas=2: host 0's death is absorbed by failover (no hedge, no
    masked knapsack), but its members are left one-replica; maintenance
    re-places them on surviving hosts so ANY further single host death
    strands nobody.  llm-blender selects every member, so the failing
    host is guaranteed traffic."""
    scenario = Scenario(
        name="replica-loss",
        arrivals=ArrivalProcess("steady", rate=2.0),
        n_requests=12, seed=0, hosts=4, replicas=2, rebalance=True,
        host_failures=((0, (0,)),),
        mix=((1.0, {"policy": "llm-blender"}),),
    )
    sched = _sched(stack, policy="llm-blender")
    report = _run(sched, scenario)
    router = sched.server.backend
    assert isinstance(router, ClusterRouter)

    assert report.served == report.n  # the death was invisible to callers
    assert report.stats["host_hedges"] == 0
    assert router.stats["failovers"] >= 1

    moves = [e for e in report.trace if e["event"] == "rebalance"]
    assert moves == [
        {"tick": 2, "event": "rebalance", "member": 1, "host": 2},
        {"tick": 2, "event": "rebalance", "member": 5, "host": 3},
        {"tick": 2, "event": "rebalance", "member": 6, "host": 3},
        {"tick": 2, "event": "rebalance", "member": 7, "host": 2},
    ]
    assert router.plan.under_replicated() == []
    # redundancy is genuinely restored: any further single host death
    # leaves every member with a surviving replica
    for h in router.plan.alive_hosts():
        dead = router.plan.dead_hosts | {h}
        stranded = [p.member_idx for p in router.plan.placements
                    if all(x in dead for x in p.hosts)]
        assert stranded == []


def test_rebalance_survives_second_death(stack):
    """After the rebalance above, killing one of the hosts that absorbed
    the re-placed replicas still strands nobody — the batch fails over
    again instead of hedging."""
    scenario = Scenario(
        name="replica-loss-2",
        arrivals=ArrivalProcess("steady", rate=2.0),
        n_requests=16, seed=0, hosts=4, replicas=2, rebalance=True,
        host_failures=((0, (0,)), (2, (8,))),
        mix=((1.0, {"policy": "llm-blender"}),),
    )
    sched = _sched(stack, policy="llm-blender")
    report = _run(sched, scenario)
    router = sched.server.backend
    assert report.served == report.n
    assert report.stats["host_hedges"] == 0  # both deaths absorbed
    assert router.stats["host_faults"] == 2
    assert router.plan.dead_members() == []
    # baseline equivalence: failover + rebalance never changed a byte
    offline = _server(stack, policy="llm-blender").serve_requests(
        report.requests)
    assert _texts(report) == [r.text for r in offline]


# ---------------------------------------------------------------------------
# Random chaos property: fan-out + recovery == sequential reference
# ---------------------------------------------------------------------------

_PROPERTY_STACK = None


@pytest.fixture(autouse=True)
def _property_stack(stack):
    global _PROPERTY_STACK
    _PROPERTY_STACK = stack
    yield


@settings(max_examples=5, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_hosts=st.sampled_from([2, 3, 4]),
    replicas=st.sampled_from([1, 2]),
    probation=st.integers(0, 3),
)
def test_random_chaos_fanout_equals_sequential(seed, n_hosts, replicas,
                                               probation):
    """Random failure schedules, recoveries, and probation windows: the
    set of served requests (and every served byte) under fan-out +
    recovery equals the sequential reference, and no generation call is
    ever dispatched to a host that was dead at dispatch time."""
    stack = _PROPERTY_STACK
    rng = np.random.default_rng(seed)
    replicas = min(replicas, n_hosts)
    n_fail = int(rng.integers(1, 3))
    hosts_failing = rng.choice(n_hosts, size=min(n_fail, n_hosts),
                               replace=False)
    host_failures = tuple(
        (int(h), tuple(sorted(set(
            int(i) for i in rng.integers(0, 6, size=rng.integers(1, 3))))))
        for h in hosts_failing)
    host_recoveries = tuple(
        (int(h), (int(rng.integers(2, 9)),))
        for h in hosts_failing if rng.random() < 0.5)
    base = Scenario(
        name=f"chaos-{seed}",
        arrivals=ArrivalProcess("steady", rate=2.0),
        n_requests=6, seed=seed, deadline_ticks=4,
        hosts=n_hosts, replicas=replicas,
        host_failures=host_failures,
        host_recoveries=host_recoveries, probation_ticks=probation,
    )
    reports = {}
    for fanout in (False, True):
        sched = _sched(stack, max_batch_size=3)
        sim = TrafficSimulator(
            sched, dataclasses.replace(base, fanout=fanout), RECORDS)
        router = sched.server.backend
        assert isinstance(router, ClusterRouter)
        router.record_audit = True
        try:
            reports[fanout] = sim.run()
        finally:
            router.close()
        # no dispatch ever routed to a host that was dead at dispatch time
        assert not any(was_dead for _, _, _, was_dead in router.audit)
    seq, fan = reports[False], reports[True]
    assert _texts(fan) == _texts(seq)
    assert fan.trace == seq.trace
    assert fan.stats == seq.stats
    assert ([type(e).__name__ for e in fan.errors]
            == [type(e).__name__ for e in seq.errors])


class _RealFault:
    """Backend wrapper raising a *real* (non-injected) HostFailure from
    inside shard execution, once, for one member — the mid-flight fault
    the planning pass cannot see."""

    def __init__(self, inner, host, member):
        self.inner, self.host, self.member = inner, host, member
        self.fired = False

    def num_members(self):
        return self.inner.num_members()

    def generate(self, j, records, caps):
        if j == self.member and not self.fired:
            self.fired = True
            raise HostFailure(self.host,
                              cause=RuntimeError("real device fault"))
        return self.inner.generate(j, records, caps)


def test_fanout_real_fault_heals_shard_tail(stack):
    """A real HostFailure mid-shard (not an injected, planning-time one)
    with replicas=2: the router absorbs the death, re-serves the faulted
    call AND the aborted shard tail on the surviving replicas, retires
    the dead host's executor, and the caller sees baseline bytes.
    Regression: the tail used to be dropped (KeyError → whole-batch
    failure) and the retired executor respawned."""
    server = _server(stack, policy="llm-blender")
    plan = PlacementPlan.auto(DEFAULT_POOL, n_hosts=4, replicas=2)
    host = next(h.host_id for h in plan.hosts
                if len([j for j in plan.members_on_host(h.host_id)
                        if plan.primary_host(j) == h.host_id]) >= 2)
    victim = min(j for j in plan.members_on_host(host)
                 if plan.primary_host(j) == host)
    router = ClusterRouter(_RealFault(server.backend, host, victim),
                           plan=plan, fanout=True)
    server.backend = router
    try:
        from repro.serve import requests_from_records
        reqs = requests_from_records(RECORDS[:4])
        out = server.serve_requests(reqs)
        assert router.stats["host_faults"] == 1
        assert router.stats["failovers"] == 1
        assert router.plan.dead_hosts == {host}
        assert host not in router._pool.live_hosts()
        baseline = _server(stack, policy="llm-blender").serve_requests(reqs)
        assert [r.text for r in out] == [r.text for r in baseline]
    finally:
        router.close()


# ---------------------------------------------------------------------------
# PR 4 hardening paths survive fan-out mode
# ---------------------------------------------------------------------------


def test_total_outage_fails_batch_but_resolves_futures_under_fanout(stack):
    """Every host dead with fanout=True: the in-flight batch fails with
    HostFailure (futures resolved, never hung) and batches formed after
    the total outage fail with the clear no-servable-members error."""
    server = _server(stack, budget=0.2)
    plan = PlacementPlan.round_robin(N_POOL, 2)
    router = ClusterRouter(server.backend, plan=plan, fanout=True,
                           host_failures={0: (0, 1, 2, 3),
                                          1: (0, 1, 2, 3)})
    server.backend = router
    sched = Scheduler(server, max_batch_size=2, max_wait_ticks=10)
    try:
        futs = []
        with pytest.raises(HostFailure):
            for r in RECORDS[:2]:
                futs.append(sched.submit(
                    EnsembleRequest(query=r.query, record=r)))
        assert sched.last_submitted is not None and sched.last_submitted.done()
        with pytest.raises(HostFailure):
            sched.last_submitted.result()

        with pytest.raises(RuntimeError, match="no servable pool members"):
            for r in RECORDS[2:4]:
                sched.submit(EnsembleRequest(query=r.query, record=r))
        assert sched.last_submitted.done()
        with pytest.raises(RuntimeError, match="no servable pool members"):
            sched.last_submitted.result()
    finally:
        router.close()


def test_async_result_after_close_resolves_under_fanout(stack):
    """result() on a queued request after close() must resolve every
    popped future with the closed-worker cause — with the fan-out router
    installed, exactly like the plain backend regression."""
    server = _server(stack, budget=0.2)
    router = ClusterRouter(server.backend,
                           plan=PlacementPlan.auto(DEFAULT_POOL, n_hosts=4),
                           fanout=True)
    server.backend = router
    sched = Scheduler(server, max_batch_size=8, max_wait_ticks=10, sync=False)
    try:
        f1 = sched.submit(EnsembleRequest(query=RECORDS[0].query,
                                          record=RECORDS[0]))
        f2 = sched.submit(EnsembleRequest(query=RECORDS[1].query,
                                          record=RECORDS[1]))
        sched.close()
        with pytest.raises(RuntimeError, match="closed"):
            f1.result(timeout=5.0)
        assert f2.done()
        with pytest.raises(RuntimeError, match="closed"):
            f2.result(timeout=5.0)
    finally:
        router.close()


# ---------------------------------------------------------------------------
# Probe-driven health: golden traces and probe-vs-schedule revival
# ---------------------------------------------------------------------------


def test_probe_recovery_golden_trace(stack):
    """Host 0 dies at its dispatch 1 (tick 3, members [1, 7] hedged);
    the HealthMonitor adopts the dispatch-observed death and its
    half-open probe at the next probe tick (4) finds the underlying
    health returned → revives immediately.  The tick-5 dispatch is
    already unmasked — no probation schedule in the loop."""
    scenario = preset_scenarios(n_requests=16)["probe-recovery"]
    sched = _sched(stack)
    report = _run(sched, scenario)
    assert report.served == report.n == 16
    router = sched.server.backend
    assert isinstance(router, ClusterRouter)
    assert router.stats["probes"] == 16
    assert router.stats["probe_revivals"] == 1
    assert router.stats["revivals"] == 1  # probe revival counts as revival

    structural = [e for e in report.trace
                  if e["event"] in ("host_hedge", "probe_death",
                                    "probe_revive", "revive")]
    assert structural == [
        {"tick": 3, "event": "host_hedge", "host": 0, "members": [1, 7],
         "reqs": [4, 5, 6, 7], "masked": [1, 7]},
        {"tick": 4, "event": "probe_revive", "host": 0, "recovered": [1, 7],
         "after_probes": 2},
    ]
    masked = [(e["tick"], e["masked"]) for e in report.trace
              if e["event"] == "dispatch"]
    assert masked == [(1, []), (3, [1, 7]), (5, []), (7, [])]
    # the adopted death is immediately probe-eligible: exactly one
    # half-open probe, and it succeeds
    half_open = [e for e in report.trace
                 if e["event"] == "probe" and e["half_open"]]
    assert half_open == [{"tick": 4, "event": "probe", "host": 0, "probe": 1,
                          "ok": True, "half_open": True}]
    # post-revival responses equal the plain offline path (no masking)
    post = list(range(8, 16))
    offline = _server(stack, budget=0.2).serve_requests(
        [report.requests[i] for i in post])
    assert [report.responses[i].text for i in post] == [r.text for r in offline]


def test_probe_revival_beats_schedule_revival(stack):
    """Identical outage and identical underlying-health return tick (4):
    the schedule+probation path revives at tick 5 (gap 2), the probe
    path at tick 4 (gap 1) — observed liveness is strictly faster."""
    probe_rep = _run(_sched(stack),
                     preset_scenarios(n_requests=16)["probe-recovery"])
    sched_rep = _run(_sched(stack),
                     preset_scenarios(n_requests=16)["host-recovery"])

    def gap(report, revive_event):
        hedge = next(e["tick"] for e in report.trace
                     if e["event"] == "host_hedge")
        revive = next(e["tick"] for e in report.trace
                      if e["event"] == revive_event)
        return revive - hedge

    probe_gap = gap(probe_rep, "probe_revive")
    schedule_gap = gap(sched_rep, "revive")
    assert probe_gap == 1 and schedule_gap == 2
    assert probe_gap < schedule_gap


CRASH_PROBE = Scenario(
    name="crash-on-probe",
    arrivals=ArrivalProcess("steady", rate=2.0),
    n_requests=16, seed=0, deadline_ticks=4, hosts=4,
    probe_interval=1, probe_failures=2,
    probe_faults=((0, tuple(range(12))),),
)


def test_crash_on_probe_kills_host_without_dispatch_explosion(stack):
    """Every probe to host 0 fails: the breaker opens at the second
    consecutive failure (tick 2) and strands [1, 7] — with NO
    host_hedge anywhere, because no dispatch ever hit the dead host.
    Later dispatches pre-mask the stranded members, and the failed
    half-open probes back off exponentially (ticks 3, 4, 6 with the
    default backoff 1 → 2 → 4)."""
    sched = _sched(stack)
    report = _run(sched, CRASH_PROBE)
    assert report.served == report.n == 16
    assert not any(e["event"] == "host_hedge" for e in report.trace)
    assert report.stats["host_hedges"] == 0

    deaths = [e for e in report.trace if e["event"] == "probe_death"]
    assert deaths == [{"tick": 2, "event": "probe_death", "host": 0,
                       "failures": 2, "stranded": [1, 7]}]
    masked = [(e["tick"], e["masked"]) for e in report.trace
              if e["event"] == "dispatch"]
    assert masked == [(1, []), (3, [1, 7]), (5, [1, 7]), (7, [1, 7])]
    half_open = [(e["tick"], e["ok"]) for e in report.trace
                 if e["event"] == "probe" and e["half_open"]]
    assert half_open == [(3, False), (4, False), (6, False)]

    # post-death responses equal the offline path with [1, 7] masked
    post = list(range(4, 16))
    offline = _server(stack, budget=0.2).serve_requests(
        [report.requests[i] for i in post], masked_members=frozenset({1, 7}))
    assert [report.responses[i].text for i in post] == [r.text for r in offline]


# ---------------------------------------------------------------------------
# Grey failures: straggler hedging (logical and wall-clock)
# ---------------------------------------------------------------------------


def test_grey_failure_straggler_hedge_is_byte_invisible(stack):
    """The grey-failure preset: host 0's dispatches 1-2 straggle and are
    re-routed to a replica at consume time.  The hedge fires identically
    under sequential and fan-out routing, the flaky probe on host 2
    stays below the breaker threshold, and not one output byte moves
    against the unrouted offline path."""
    base = preset_scenarios(n_requests=16)["grey-failure"]
    reports, routers = {}, {}
    for fanout in (False, True):
        sched = _sched(stack)
        reports[fanout] = _run(sched, dataclasses.replace(base, fanout=fanout))
        routers[fanout] = sched.server.backend
    seq, fan = reports[False], reports[True]
    assert fan.trace == seq.trace
    assert fan.stats == seq.stats
    assert _texts(fan) == _texts(seq)
    assert (routers[False].stats["straggler_hedges"]
            == routers[True].stats["straggler_hedges"]) and \
        routers[False].stats["straggler_hedges"] > 0
    flaky = [e for e in seq.trace if e["event"] == "probe" and not e["ok"]]
    assert [(e["host"], e["probe"]) for e in flaky] == [(2, 1)]
    assert not any(e["event"] == "probe_death" for e in seq.trace)
    offline = _server(stack, budget=0.2).serve_requests(seq.requests)
    assert _texts(seq) == [r.text for r in offline]


class _HostStraggler:
    """Wall-clock-only grey host: calls executing on ``slow_host`` sleep
    before generating (keyed on ``current_dispatch_host()``, which the
    router sets around every inner generate).  Outputs and the logical
    trace are untouched — only the shard's wall time."""

    def __init__(self, inner, slow_host, slow_s):
        self.inner, self.slow_host, self.slow_s = inner, slow_host, slow_s

    def num_members(self):
        return self.inner.num_members()

    def generate(self, j, records, caps):
        if current_dispatch_host() == self.slow_host:
            time.sleep(self.slow_s)
        return self.inner.generate(j, records, caps)


def test_shard_deadline_hedges_real_straggler_to_replica(stack):
    """fanout + replicas=2 + a wall-clock straggler host: the fan-out
    join times out on the late shard, cancels its future, and re-runs
    its unfinished orders on a replica host (earliest completion wins).
    The straggler is grey, not dead — no fault, no mask — and the
    caller sees baseline bytes."""
    server = _server(stack, policy="llm-blender")
    plan = PlacementPlan.auto(DEFAULT_POOL, n_hosts=4, replicas=2)
    router = ClusterRouter(_HostStraggler(server.backend, 0, 0.25),
                           plan=plan, fanout=True, shard_deadline_s=0.05)
    server.backend = router
    try:
        reqs = requests_from_records(RECORDS[:4])
        out = server.serve_requests(reqs)
        assert router.stats["shard_hedges"] >= 1
        assert router.plan.dead_hosts == set()
        assert router.stats["host_faults"] == 0
        baseline = _server(stack, policy="llm-blender").serve_requests(reqs)
        assert [r.text for r in out] == [r.text for r in baseline]
    finally:
        router.close()


# ---------------------------------------------------------------------------
# Graceful degradation: partial ensembles with hedging off
# ---------------------------------------------------------------------------


def test_degraded_partial_ensemble_golden_settlement(stack):
    """hedge=False + allow_degraded=True through the host-outage preset:
    the fault batch and everything after serve as partial ensembles —
    knapsack re-solved over the survivors, responses tagged with the
    missing members — and every ``degraded`` settlement event's sums are
    hand-computable from the responses and the cost matrix."""
    scenario = preset_scenarios(n_requests=12)["host-outage"]
    sched = _sched(stack, hedge=False, allow_degraded=True)
    report = _run(sched, scenario)
    assert report.served == report.n == 12
    assert sched.stats["degraded_responses"] == 8

    degraded_idx = [i for i, r in enumerate(report.responses) if r.degraded]
    assert degraded_idx == list(range(4, 12))
    for i in degraded_idx:
        r = report.responses[i]
        assert r.missing_members == (1, 7)
        assert not r.mask[[1, 7]].any()
    for i in range(4):  # pre-fault responses are full-ensemble
        assert not report.responses[i].degraded
        assert report.responses[i].missing_members == ()

    # outputs equal the offline path with the dead members masked
    offline = _server(stack, budget=0.2).serve_requests(
        [report.requests[i] for i in degraded_idx],
        masked_members=frozenset({1, 7}))
    assert ([report.responses[i].text for i in degraded_idx]
            == [r.text for r in offline])

    # survivor-cost settlement is hand-computable: each degraded
    # response's survivor_cost is the cost-matrix sum over the alive
    # columns, and each settlement event sums its batch exactly
    costs = query_cost_matrix(
        DEFAULT_POOL,
        [report.requests[i].resolve_record() for i in degraded_idx])
    alive = [j for j in range(N_POOL) if j not in (1, 7)]
    for row, i in enumerate(degraded_idx):
        assert report.responses[i].survivor_cost == pytest.approx(
            float(costs[row, alive].sum()), rel=1e-6)
    degraded_evs = [e for e in report.trace if e["event"] == "degraded"]
    assert [(e["tick"], e["reqs"], e["missing"]) for e in degraded_evs] == [
        (3, [4, 5, 6, 7], [1, 7]), (5, [8, 9, 10, 11], [1, 7])]
    # degraded settlement reports the survivor batch's own padding (full
    # rungs here), never a hedged attempt's
    assert [e["padded"] for e in degraded_evs] == [0, 0]
    for ev in degraded_evs:
        assert ev["realized"] == pytest.approx(sum(
            report.responses[i].realized_cost for i in ev["reqs"]))
        assert ev["survivor_full"] == pytest.approx(sum(
            report.responses[i].survivor_cost for i in ev["reqs"]))


# ---------------------------------------------------------------------------
# Per-batch dead-member snapshot: async pre-mask cannot race a death
# ---------------------------------------------------------------------------


def test_premask_snapshot_keeps_async_trace_stable(stack):
    """A host death lands while later batches are already queued on the
    dispatch worker: because the dead-member state is snapshot per batch
    at dispatch time on the serving thread (FIFO — every earlier batch
    has served), the async trace is byte-identical to sync, run after
    run.  This is the regression for the pre-mask race: a formation-time
    read (or a torn mid-service read) would miss the death and pay a
    spurious hedge."""
    scenario = preset_scenarios(n_requests=16)["host-outage"]

    def run(sync):
        sched = _sched(stack, sync=sync)
        return _run(sched, scenario)

    sync_a, sync_b = run(True), run(True)
    async_a, async_b = run(False), run(False)
    assert sync_a.trace == sync_b.trace == async_a.trace == async_b.trace
    assert sync_a.stats == async_a.stats
    # the batches formed after the death pre-masked it (no second hedge)
    assert sync_a.stats["host_hedges"] == 1
    masked = [e["masked"] for e in sync_a.trace if e["event"] == "dispatch"]
    assert masked[-1] != []  # later batches carried the snapshot pre-mask


def test_dead_members_snapshot_is_atomic(stack):
    """dead_members() is one consistent read under the plan lock: a
    concurrent revive cannot tear it (members of a half-revived plan)."""
    plan = PlacementPlan.round_robin(N_POOL, 4)
    router = ClusterRouter(SimRouterBackend(), plan=plan)
    plan.mark_host_dead(0)
    plan.mark_host_dead(1)
    dead = router.dead_members()
    assert dead == sorted(plan.members_on_host(0) + plan.members_on_host(1))
    plan.revive_host(0)
    assert router.dead_members() == plan.members_on_host(1)


class SimRouterBackend:
    """Minimal MemberBackend for plan-level tests (never generates)."""

    def num_members(self):
        return N_POOL

    def generate(self, member_idx, records, max_new_tokens):
        raise AssertionError("plan-level test must not generate")
