"""Attention cache-write semantics: the partition-friendly overlay prefill
write (EXPERIMENTS.md §Perf A') must be exactly equivalent to the scatter
path, and ring-buffer writes must wrap correctly."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.models.attention import (
    _mask_bias,
    _write_cache_bulk,
    _write_cache_step,
    init_cache,
)


def _mk_cache(cfg, b, slots):
    return init_cache(cfg, b, slots, jnp.float32)


def test_overlay_write_matches_scatter_semantics():
    cfg = configs.get("smollm-360m").reduced(dtype="float32")
    b, s, slots = 2, 6, 10
    cache = _mk_cache(cfg, b, slots)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = jax.random.normal(jax.random.key(0), (b, s, kv, hd))
    v = jax.random.normal(jax.random.key(1), (b, s, kv, hd))
    # right-padded: row 0 has 4 real tokens, row 1 has 6
    positions = jnp.asarray([[0, 1, 2, 3, -1, -1], [0, 1, 2, 3, 4, 5]])
    new = _write_cache_bulk(cache, {"k": k, "v": v}, positions, window=0)
    # valid slots hold the values; padded + tail slots untouched (pos=-1)
    np.testing.assert_array_equal(np.asarray(new["pos"][0]), [0, 1, 2, 3, -1, -1, -1, -1, -1, -1])
    np.testing.assert_array_equal(np.asarray(new["pos"][1]), [0, 1, 2, 3, 4, 5, -1, -1, -1, -1])
    np.testing.assert_allclose(np.asarray(new["k"][0, :4]), np.asarray(k[0, :4]))
    assert float(jnp.abs(new["k"][0, 4:]).max()) == 0.0  # pads dropped


def test_ring_buffer_wraps():
    cfg = dataclasses.replace(configs.get("smollm-360m").reduced(dtype="float32"),
                              sliding_window=4)
    b, slots = 1, 4
    cache = _mk_cache(cfg, b, slots)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    for t in range(7):
        val = jnp.full((b, kv, hd), float(t))
        cache = _write_cache_step(cache, {"k": val, "v": val}, jnp.asarray([t]), window=4)
    # positions 3..6 live in slots 3,0,1,2
    np.testing.assert_array_equal(np.asarray(cache["pos"][0]), [4, 5, 6, 3])
    assert float(cache["k"][0, 0, 0, 0]) == 4.0


def test_mask_bias_window_and_validity():
    q_pos = jnp.asarray([[5]])
    k_pos = jnp.asarray([[-1, 3, 4, 5, 6]])
    bias = _mask_bias(q_pos, k_pos, window=0)[0, 0, 0]
    assert (np.asarray(bias) < -1e20).tolist() == [True, False, False, False, True]
    bias_w = _mask_bias(q_pos, k_pos, window=2)[0, 0, 0]
    assert (np.asarray(bias_w) < -1e20).tolist() == [True, True, False, False, True]


def test_prefill_then_decode_with_window_cache():
    """Windowed prefill+decode stays consistent with stepwise decode."""
    cfg = dataclasses.replace(configs.get("smollm-360m").reduced(dtype="float32"),
                              sliding_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 1, 12
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    # stepwise decode from scratch
    cache_a = model.init_cache(B, S + 4)
    out_a = None
    for t in range(S):
        out_a, cache_a = model.decode_step(params, toks[:, t:t + 1],
                                           jnp.asarray([t]), cache_a)
    # prefill then nothing — last-token logits must match
    cache_b = model.init_cache(B, S + 4)
    logits_b, cache_b = model.prefill(params, toks, cache_b)
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(logits_b), atol=2e-4)
