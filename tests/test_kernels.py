"""Per-kernel validation: shape/dtype sweeps + property tests against the
pure-jnp oracles (interpret=True executes kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.knapsack import knapsack_select
from repro.kernels.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import gqa_attention_ref
from repro.kernels.knapsack import knapsack_select_pallas, knapsack_select_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.models.ssm import ssd_chunked, ssd_reference

# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (b, h, kv, sq, hd, causal, window, bq, bk, dtype)
    (2, 4, 2, 64, 32, True, 0, 16, 16, jnp.float32),
    (1, 8, 2, 96, 64, True, 0, 32, 32, jnp.float32),
    (2, 2, 2, 37, 16, True, 0, 16, 16, jnp.float32),
    (1, 4, 4, 64, 32, False, 0, 16, 16, jnp.float32),
    (1, 4, 2, 64, 32, True, 24, 16, 16, jnp.float32),
    (1, 4, 1, 48, 32, True, 0, 16, 16, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_oracle(case):
    b, h, kv, s, hd, causal, window, bq, bk, dtype = case
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, hd), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, hd), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, hd), dtype)
    out = flash_attention(q, k, v, causal, window, bq, bk)
    ref = gqa_attention_ref(q, k, v, causal, window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@settings(max_examples=12, deadline=None)
@given(
    s=st.integers(9, 80),
    hd=st.sampled_from([8, 16, 32]),
    group=st.sampled_from([1, 2, 3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_property(s, hd, group, seed):
    kv = 2
    h = kv * group
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (1, h, s, hd))
    k = jax.random.normal(ks[1], (1, kv, s, hd))
    v = jax.random.normal(ks[2], (1, kv, s, hd))
    out = flash_attention(q, k, v, True, 0, 16, 16)
    ref = gqa_attention_ref(q, k, v, True, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


def test_flash_attention_row_convexity():
    """Each output row is a convex combination of V rows (softmax property)."""
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 32, 16))
    k = jax.random.normal(ks[1], (1, 2, 32, 16))
    v = jnp.ones((1, 2, 32, 16))
    out = flash_attention(q, k, v, True, 0, 16, 16)
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-5)


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (2, 2, 4, 64, 32, 0, 32, jnp.float32),
    (1, 4, 2, 100, 64, 0, 64, jnp.float32),
    (2, 2, 5, 64, 32, 24, 32, jnp.float32),
    (1, 1, 8, 128, 32, 0, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_matches_oracle(case):
    b, kv, g, s, hd, window, bk, dtype = case
    ks = jax.random.split(jax.random.key(0), 4)
    q = jax.random.normal(ks[0], (b, kv, g, hd), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, hd), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, hd), dtype)
    pos = jax.random.permutation(ks[3], jnp.arange(s))[None].repeat(b, 0) - 5
    cur = jnp.full((b,), s * 2 // 3, jnp.int32)
    out = decode_attention(q, k, v, pos, cur, window, block_k=bk)
    ref = decode_attention_ref(q, k, v, pos, cur, window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_decode_attention_ring_buffer_invariance():
    """Permuting cache slots (with their positions) must not change output."""
    ks = jax.random.split(jax.random.key(2), 4)
    b, kv, g, s, hd = 1, 2, 2, 48, 16
    q = jax.random.normal(ks[0], (b, kv, g, hd))
    k = jax.random.normal(ks[1], (b, kv, s, hd))
    v = jax.random.normal(ks[2], (b, kv, s, hd))
    pos = jnp.arange(s)[None]
    cur = jnp.array([30])
    out1 = decode_attention(q, k, v, pos, cur, 0, block_k=16)
    perm = jax.random.permutation(ks[3], jnp.arange(s))
    out2 = decode_attention(q, k[:, :, perm], v[:, :, perm], pos[:, perm], cur, 0, block_k=16)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    (2, 64, 3, 16, 32, 16, jnp.float32),
    (1, 50, 2, 8, 16, 16, jnp.float32),
    (1, 128, 4, 32, 64, 32, jnp.float32),
    (1, 64, 2, 16, 16, 16, jnp.bfloat16),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_sequential(case):
    b, s, nh, hd, n, chunk, dtype = case
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (b, s, nh, hd), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh))).astype(dtype)
    a = jnp.exp(-jax.nn.softplus(jax.random.normal(ks[2], (b, s, nh)))).astype(dtype)
    bm = jax.random.normal(ks[3], (b, s, n), dtype)
    cm = jax.random.normal(ks[4], (b, s, n), dtype)
    y, h = ssd_scan(x, dt, a, bm, cm, chunk=chunk)
    yr, hr = ssd_reference(x, dt, a, bm, cm)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(h, np.float32), np.asarray(hr, np.float32), atol=tol, rtol=tol)


@settings(max_examples=8, deadline=None)
@given(s=st.integers(5, 70), chunk=st.sampled_from([8, 16]), seed=st.integers(0, 999))
def test_ssd_chunk_invariance(s, chunk, seed):
    """Kernel output is independent of chunk size and matches pure-jnp chunked."""
    b, nh, hd, n = 1, 2, 8, 8
    ks = jax.random.split(jax.random.key(seed), 5)
    x = jax.random.normal(ks[0], (b, s, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    a = jnp.exp(-jax.nn.softplus(jax.random.normal(ks[2], (b, s, nh))))
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    y, h = ssd_scan(x, dt, a, bm, cm, chunk=chunk)
    yj, hj = ssd_chunked(x, dt, a, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yj), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hj), atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# Knapsack
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q,n,budget", [(5, 8, 64), (16, 8, 256), (3, 16, 128)])
def test_knapsack_kernel_matches_lax(q, n, budget):
    rng = np.random.default_rng(q * 1000 + n)
    profits = jnp.asarray(rng.uniform(0.1, 5.0, (q, n)), jnp.float32)
    costs = jnp.asarray(rng.integers(1, budget // 2, (q, n)), jnp.int32)
    a = knapsack_select_pallas(profits, costs, budget)
    b = knapsack_select(profits, costs, budget)
    # the take-tensor + backtrack oracle is an independent derivation of the
    # same Algorithm-1 selection — exact match, not just equal value
    ref = knapsack_select_ref(profits, costs, budget)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ref))
    va = jnp.sum(jnp.where(a, profits, 0), 1)
    vb = jnp.sum(jnp.where(b, profits, 0), 1)
    np.testing.assert_allclose(np.asarray(va), np.asarray(vb), rtol=1e-6)
    assert bool(jnp.all(jnp.sum(jnp.where(a, costs, 0), 1) <= budget))


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 10),
    budget=st.integers(8, 128),
    seed=st.integers(0, 2**31 - 1),
)
def test_knapsack_kernel_optimality(n, budget, seed):
    """Kernel value == brute-force optimum; cost constraint holds."""
    rng = np.random.default_rng(seed)
    profits = rng.uniform(0.01, 3.0, (1, n)).astype(np.float32)
    costs = rng.integers(1, budget + 10, (1, n)).astype(np.int32)
    sel = np.asarray(knapsack_select_pallas(jnp.asarray(profits), jnp.asarray(costs), budget))[0]
    best = 0.0
    for mask in range(1 << n):
        c = sum(int(costs[0, i]) for i in range(n) if mask >> i & 1)
        if c <= budget:
            best = max(best, sum(float(profits[0, i]) for i in range(n) if mask >> i & 1))
    got = float(profits[0][sel].sum())
    assert got <= best + 1e-5
    assert got >= best - 1e-4
    assert int(costs[0][sel].sum()) <= budget
