"""Sharding rules + param-spec inference tests (no multi-device needed:
these validate spec construction against a small host mesh)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import configs
from repro.models import build_model
from repro.sharding.api import AxisRules, axis_rules, current_rules, default_axis_rules, logical_constraint
from repro.sharding.params import infer_param_specs, spec_drop_dim


@pytest.fixture(scope="module")
def mesh():
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_axis_rules_resolution(mesh):
    rules = default_axis_rules(mesh)
    spec = rules.resolve(("batch", None, "heads"))
    assert spec == P("data", None, "model")  # pod filtered out (absent)


def test_axis_rules_dedup(mesh):
    rules = AxisRules(mesh=mesh, rules={"a": "model", "b": "model"})
    # same mesh axis cannot appear twice
    assert rules.resolve(("a", "b")) == P("model", None)


def test_rules_context(mesh):
    assert current_rules() is None
    with axis_rules(default_axis_rules(mesh)) as r:
        assert current_rules() is r
        x = jnp.ones((4, 4))
        # constraint on 1-sized mesh is a no-op but must not error
        logical_constraint(x, "batch", "heads")
    assert current_rules() is None


def test_param_spec_inference(mesh):
    rules = default_axis_rules(mesh)
    cfg = configs.get("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    specs = infer_param_specs(shapes, rules)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    # every leaf got a PartitionSpec
    for path, spec in flat:
        assert isinstance(spec, P)
    # tiny model: everything replicates (below size threshold)
    assert all(spec == P() for _, spec in flat)


def test_param_spec_inference_large():
    """Full-size config: key tensors get model/fsdp shards with leading
    layer-stack dim unsharded; indivisible dims are dropped."""
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1, 1)
    mesh = Mesh(dev, ("pod", "data", "model"))
    rules = default_axis_rules(mesh)
    cfg = configs.get("qwen2.5-32b")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    specs = infer_param_specs(shapes, rules)
    segs = specs["segs"]["0"]
    assert segs["attn"]["wq"][0] is None  # stacked layer dim
    assert "model" in str(segs["attn"]["wq"])  # heads sharded
    assert specs["embed"] == P("model", ("pod", "data"))
    # bias [L, H, hd] small -> replicated
    assert segs["attn"]["bq"] == P()


def test_moe_expert_specs():
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1, 1)
    mesh = Mesh(dev, ("pod", "data", "model"))
    rules = default_axis_rules(mesh)
    cfg = configs.get("deepseek-v3-671b")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    specs = infer_param_specs(shapes, rules)
    wi = specs["segs"]["1"]["moe"]["experts"]["wi"]
    assert wi[1] == "model" and wi[2] == ("pod", "data")  # experts x fsdp


def test_spec_drop_dim():
    s = P("model", ("pod", "data"), None)
    assert spec_drop_dim(s, 3, -1) == P("model", ("pod", "data"))
    assert spec_drop_dim(s, 3, -2) == P("model", None)


def test_divisibility_dropping(mesh):
    rules = AxisRules(mesh=Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model")),
                      rules={"heads": "model"})
    from repro.sharding.params import _check_divisible

    # 15 heads % 1 shard == 0 here; fake a 16-wide mesh via rules on shape
    spec = _check_divisible(("heads",), (15,), rules)
    assert isinstance(spec, P)
