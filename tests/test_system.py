"""End-to-end behaviour tests for the full MODI system."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import EpsilonConstraint, ModiPolicy, bartscore, build_predictor
from repro.data import (
    DEFAULT_POOL,
    TOKENIZER,
    generate_dataset,
    pool_responses,
    scorer_batches,
)
from repro.models import build_model
from repro.optim import AdamW
from repro.serve import EnsembleServer
from repro.train import repeat_batches, train


@pytest.fixture(scope="module")
def trained_scorer():
    """Briefly trained BARTScore scorer (shared across tests)."""
    recs = generate_dataset(600, seed=0)
    scorer = build_model(configs.get("bartscore-scorer"))
    params = scorer.init(jax.random.key(1))
    res = train(
        lambda p, b: scorer.loss(p, b), params,
        repeat_batches(lambda ep: scorer_batches(recs, DEFAULT_POOL, 16, 96, 32, seed=ep)),
        steps=120, optimizer=AdamW(learning_rate=1.5e-3), log_fn=lambda s: None,
    )
    return scorer, res.params


def _score(scorer, params, recs, texts):
    refs = TOKENIZER.pad_batch(
        [TOKENIZER.encode(r.reference, bos=True, eos=True) for r in recs], 32)
    mask = (refs != TOKENIZER.pad_id).astype(np.float32)
    cands = TOKENIZER.pad_batch([TOKENIZER.encode(t) for t in texts], 64)
    return np.asarray(bartscore(scorer, params, jnp.asarray(cands), jnp.asarray(refs),
                                jnp.asarray(mask)))


def test_scorer_training_reduces_loss(trained_scorer):
    scorer, params = trained_scorer
    recs = generate_dataset(32, seed=9)
    batch = next(iter(scorer_batches(recs, DEFAULT_POOL, 16, 96, 32, seed=1)))
    loss, _ = scorer.loss(params, batch)
    assert float(loss) < 3.0  # random init is ~ln(512) = 6.24


def test_bartscore_is_negative_and_finite(trained_scorer):
    scorer, params = trained_scorer
    recs = generate_dataset(8, seed=3)
    s = _score(scorer, params, recs, [r.reference for r in recs])
    assert np.isfinite(s).all() and (s < 0).all()


def test_quality_ordering_strong_vs_weak_member(trained_scorer):
    """BARTScore of a strong member's responses beats a weak member's on
    its strong domain (the signal MODI's predictor learns)."""
    scorer, params = trained_scorer
    recs = [r for r in generate_dataset(600, seed=5) if r.domain == "add"][:48]
    responses = pool_responses(DEFAULT_POOL, recs, seed=1)
    strong = _score(scorer, params, recs, [responses[i][5] for i in range(len(recs))])  # koala .90
    weak = _score(scorer, params, recs, [responses[i][3] for i in range(len(recs))])  # stablelm .35
    assert strong.mean() > weak.mean()


def test_end_to_end_modi_under_budget(trained_scorer):
    """Full pipeline: predictor -> knapsack -> generation -> fusion, with
    the realized cost within ε of the full-ensemble cost."""
    pred = build_predictor(num_models=len(DEFAULT_POOL))
    pp = pred.init(jax.random.key(0))
    fuser = build_model(configs.get("gen-fuser"))
    fp = fuser.init(jax.random.key(1))
    srv = EnsembleServer(DEFAULT_POOL, ModiPolicy(EpsilonConstraint(0.2)), pred, pp, fuser, fp)
    recs = generate_dataset(8, seed=123)
    res = srv.serve(recs)
    assert (res.cost_fraction <= 0.2 + 1e-6).all()
    assert (res.mask.sum(1) >= 1).all()
    assert len(res.responses) == 8
