"""Golden-file pin of knapsack tie-breaking across an ε/budget grid.

Algorithm 1's backtrack resolves DP ties with the *ties-keep-not-taken*
rule, and which side of a tie a member lands on changes the selection —
silently, if nothing pins it.  This test freezes the exact selections of
``select_under_budget`` over a grid of ε fractions × bucket counts, on
inputs engineered for ties (integer profits, repeated integer costs), for
BOTH DP backends (``impl="lax"`` and ``impl="pallas"``): a future kernel
rewrite that shifts any tie breaks the diff here, not in production.

Regenerate (only when a selection change is *intended* and reviewed):

    PYTHONPATH=src python tests/test_knapsack_golden.py --regen
"""

from __future__ import annotations

import json
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EpsilonConstraint, select_under_budget

GOLDEN = pathlib.Path(__file__).parent / "golden" / "knapsack_ties.json"

FRACTIONS = (0.05, 0.1, 0.2, 0.35, 0.5, 0.75, 1.0)
BUCKETS = (64, 256)
Q, N = 4, 10


def _tie_heavy_inputs():
    """Integer profits and repeated integer costs — maximal tie pressure."""
    rng = np.random.default_rng(0xA1)
    # BARTScore-like negative integer scores: many equal profits post-shift
    quality = rng.integers(-4, 0, (Q, N)).astype(np.float32)
    # few distinct cost levels so cost ties are common too
    costs = (rng.integers(1, 6, (Q, N)) * 1e11).astype(np.float32)
    return quality, costs


def _grid_masks(impl: str) -> dict:
    quality, costs = _tie_heavy_inputs()
    out = {}
    for frac in FRACTIONS:
        for buckets in BUCKETS:
            mask = np.asarray(select_under_budget(
                jnp.asarray(quality), jnp.asarray(costs),
                EpsilonConstraint(frac, buckets=buckets), impl=impl,
            ))
            out[f"eps={frac}/buckets={buckets}"] = [
                "".join("1" if x else "0" for x in row) for row in mask
            ]
    return out


@pytest.mark.parametrize("impl", ["lax", "pallas"])
def test_knapsack_tie_breaking_pinned(impl):
    golden = json.loads(GOLDEN.read_text())
    masks = _grid_masks(impl)
    assert masks.keys() == golden["masks"].keys()
    for key in golden["masks"]:
        assert masks[key] == golden["masks"][key], (
            f"{impl} selection drifted from golden at {key} — tie-breaking "
            "changed; if intended, regenerate with --regen and review the diff"
        )


def test_golden_grid_is_tie_heavy():
    """The pin is only meaningful if ties actually occur: several grid
    points must select strictly fewer members than a greedy fill would,
    and the two backends must agree with each other."""
    lax = _grid_masks("lax")
    assert lax == _grid_masks("pallas")
    sizes = {k: sum(row.count("1") for row in v) for k, v in lax.items()}
    assert len(set(sizes.values())) > 3  # the grid spans distinct regimes


if __name__ == "__main__":
    import argparse
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true")
    if ap.parse_args().regen:
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(
            {"fractions": FRACTIONS, "buckets": BUCKETS, "q": Q, "n": N,
             "masks": _grid_masks("lax")}, indent=2) + "\n")
        print(f"wrote {GOLDEN}")
