"""Static-shape serving fast path: bucketed jit dispatch, donated decode
caches, compile-cache behaviour through the Scheduler, and the
backtrack-free bitmask knapsack."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.core import build_predictor, make_policy
from repro.core.knapsack import knapsack_reference, knapsack_select
from repro.data import DEFAULT_POOL, TOKENIZER, generate_dataset
from repro.models import build_model
from repro.serve import (
    BucketLadder,
    DecoderGenerateDispatcher,
    EncDecGenerateDispatcher,
    EnsembleServer,
    Scheduler,
    greedy_generate,
    greedy_generate_encdec,
    requests_from_records,
)


@pytest.fixture(scope="module")
def decoder():
    cfg = configs.get("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg)
    return model, model.init(jax.random.key(0))


@pytest.fixture(scope="module")
def fuser():
    model = build_model(configs.get("gen-fuser"))
    return model, model.init(jax.random.key(1))


@pytest.fixture(scope="module")
def stack():
    pred = build_predictor(num_models=len(DEFAULT_POOL))
    pp = pred.init(jax.random.key(0))
    fuser = build_model(configs.get("gen-fuser"))
    fp = fuser.init(jax.random.key(1))
    return pred, pp, fuser, fp


# ---------------------------------------------------------------------------
# Bucket ladder
# ---------------------------------------------------------------------------


def test_bucket_ladder_rounding_and_growth():
    ladder = BucketLadder(batch=(1, 2, 4), new_tokens=(8, 32), prompt=(96,))
    assert ladder.batch_bucket(1) == 1
    assert ladder.batch_bucket(3) == 4
    assert ladder.batch_bucket(4) == 4
    assert ladder.batch_bucket(5) == 8  # beyond the ladder -> next pow2
    assert ladder.new_bucket(9) == 32
    assert ladder.prompt_bucket(96) == 96
    assert ladder.prompt_bucket(97) == 128


# ---------------------------------------------------------------------------
# Dispatcher correctness: padding + donated-cache reuse must be invisible
# ---------------------------------------------------------------------------


def test_decoder_dispatch_matches_direct(decoder):
    model, params = decoder
    dispatch = DecoderGenerateDispatcher(model, params)
    prompts = TOKENIZER.pad_batch(
        [TOKENIZER.encode("hello there", bos=True),
         TOKENIZER.encode("hi", bos=True),
         TOKENIZER.encode("a much longer prompt here", bos=True)], 30)
    fast = dispatch(prompts, max_new=5)  # b=3 -> bucket 4; s=30 -> 32; new 5 -> 8
    direct = greedy_generate(model, params, prompts, max_new=5)
    assert fast.shape == direct.shape == (3, 5)
    np.testing.assert_array_equal(fast, direct)


def test_decoder_dispatch_cache_reuse_is_clean(decoder):
    """Second same-bucket call reuses the donated cache; stale state from the
    first generation must not leak into the second."""
    model, params = decoder
    dispatch = DecoderGenerateDispatcher(model, params)
    a = TOKENIZER.pad_batch([TOKENIZER.encode("first query words", bos=True)], 16)
    b = TOKENIZER.pad_batch([TOKENIZER.encode("second", bos=True)], 16)
    dispatch(a, max_new=6)
    second = dispatch(b, max_new=6)
    np.testing.assert_array_equal(second, greedy_generate(model, params, b, max_new=6))
    assert dispatch.compiles == 1  # same bucket both times


def test_encdec_dispatch_matches_direct_and_reuses(fuser):
    model, params = fuser
    dispatch = EncDecGenerateDispatcher(model, params)
    enc = TOKENIZER.pad_batch(
        [TOKENIZER.encode("fuse this"), TOKENIZER.encode("and this"),
         TOKENIZER.encode("third row")], 16)
    first = dispatch(enc, max_new=5)
    np.testing.assert_array_equal(
        first, greedy_generate_encdec(model, params, enc, max_new=5))
    # same bucket again (3 -> batch bucket 4), fresh content, cache reused
    enc2 = TOKENIZER.pad_batch(
        [TOKENIZER.encode("other stuff"), TOKENIZER.encode("more"),
         TOKENIZER.encode("rows")], 16)
    again = dispatch(enc2, max_new=5)
    np.testing.assert_array_equal(
        again, greedy_generate_encdec(model, params, enc2, max_new=5))
    assert dispatch.compiles == 1


def test_dispatch_mega_batch_bypasses_buckets(decoder):
    """Batches beyond the top ladder rung (one-shot offline evals) run at
    their exact shape instead of padding to the next power of two and
    pinning an oversized donated cache."""
    model, params = decoder
    dispatch = DecoderGenerateDispatcher(
        model, params, ladder=BucketLadder(batch=(2,), new_tokens=(8,), prompt=(16,)))
    prompts = TOKENIZER.pad_batch(
        [TOKENIZER.encode(f"q{i}", bos=True) for i in range(3)], 12)
    out = dispatch(prompts, max_new=4)  # 3 > top rung 2 -> direct path
    np.testing.assert_array_equal(
        out, greedy_generate(model, params, prompts, max_new=4))
    assert dispatch.stats["direct_calls"] == 1
    assert dispatch.buckets == []  # no oversized bucket entry was cached


def test_dispatch_zero_recompiles_across_sizes(decoder):
    model, params = decoder
    dispatch = DecoderGenerateDispatcher(
        model, params, ladder=BucketLadder(batch=(4,), new_tokens=(8,), prompt=(16,)))
    for b in (2, 3, 4):
        prompts = TOKENIZER.pad_batch(
            [TOKENIZER.encode(f"q{i}", bos=True) for i in range(b)], 12)
        out = dispatch(prompts, max_new=4)
        assert out.shape == (b, 4)
    assert dispatch.compiles == 1
    assert dispatch.stats["calls"] == 3


def test_dispatch_warm_precompiles(fuser):
    model, params = fuser
    dispatch = EncDecGenerateDispatcher(model, params)
    dispatch.warm([(2, 16, 8)])
    assert dispatch.compiles == 1
    dispatch(
        TOKENIZER.pad_batch([TOKENIZER.encode("hello"), TOKENIZER.encode("hi")], 16),
        max_new=8,
    )
    assert dispatch.compiles == 1  # warm covered the (2, 16, 8) bucket


# ---------------------------------------------------------------------------
# Compile-cache behaviour through the Scheduler (acceptance criterion)
# ---------------------------------------------------------------------------


def test_scheduler_compiles_generate_once_across_micro_batches(stack):
    """Three consecutive differently-sized micro-batches that share one
    bucket must compile the generate callables exactly once: the second
    and third batches trigger zero new compilations."""
    pred, pp, fuser, fp = stack
    server = EnsembleServer(
        DEFAULT_POOL, make_policy("modi", budget=0.2), pred, pp, fuser, fp,
        bucket_ladder=BucketLadder(batch=(4,), new_tokens=(32,)),
    )
    sched = Scheduler(server, max_batch_size=4, max_wait_ticks=1)
    recs = generate_dataset(9, seed=21)
    counts = []
    for size, start in ((4, 0), (3, 4), (2, 7)):
        futures = [
            sched.submit(r)
            for r in requests_from_records(recs[start:start + size])
        ]
        sched.flush()
        for f in futures:
            f.result()
        counts.append(server.generate_compiles()["total"])
    assert counts[0] == 1  # first batch compiles the bucket
    assert counts[1] == counts[0]  # zero new compilations
    assert counts[2] == counts[0]


def test_server_warm_shapes_precompile(stack):
    pred, pp, fuser, fp = stack
    server = EnsembleServer(
        DEFAULT_POOL, make_policy("modi", budget=0.2), pred, pp, fuser, fp,
        warm_shapes=[(2, 32)],
    )
    assert server.generate_compiles()["total"] == 1
    server.serve_requests(requests_from_records(generate_dataset(2, seed=5)))
    assert server.generate_compiles()["total"] == 1  # bucket already warm


# ---------------------------------------------------------------------------
# Member-token cap plumbing (satellites: no hidden 64-token truncation,
# no double encode round trip)
# ---------------------------------------------------------------------------


def test_member_texts_respect_per_request_cap(stack):
    pred, pp, fuser, fp = stack
    server = EnsembleServer(DEFAULT_POOL, make_policy("llm-blender"),
                            pred, pp, fuser, fp)
    rec = generate_dataset(1, seed=17)[0]
    resp = server.serve_requests(
        requests_from_records([rec], max_new_tokens=6))[0]
    assert all(t is None or len(TOKENIZER.encode(t)) <= 6
               for t in resp.member_texts)


def test_long_member_outputs_not_truncated_at_64(stack):
    """The old fusion path hardcoded a 64-token member cap; responses longer
    than 64 tokens must now survive into fusion intact."""
    from repro.data.mixinstruct import DOMAIN_NAMES, Record

    pred, pp, fuser, fp = stack
    server = EnsembleServer(DEFAULT_POOL, make_policy("llm-blender"),
                            pred, pp, fuser, fp, max_new_tokens=128)
    rec = Record(query="summarize the plan",
                 reference="the quick brown fox jumps over the lazy dog " * 3,
                 domain=DOMAIN_NAMES[0], domain_id=0)
    assert len(rec.reference.encode()) > 64
    resp = server.serve_requests(requests_from_records([rec]))[0]
    longest = max(len(TOKENIZER.encode(t))
                  for t in resp.member_texts if t is not None)
    assert longest > 64  # would have been clamped to 64 before

    capped = EnsembleServer(DEFAULT_POOL, make_policy("llm-blender"),
                            pred, pp, fuser, fp, max_new_tokens=128,
                            max_member_tokens=16)
    assert capped.max_member_tokens == 16


def test_sim_backend_truncates_per_row():
    from repro.serve import SimBackend

    sim = SimBackend(DEFAULT_POOL, seed=3)
    recs = generate_dataset(3, seed=9)
    texts = sim.generate(2, recs, [4, 8, 64])
    assert all(len(TOKENIZER.encode(t)) <= c for t, c in zip(texts, [4, 8, 64]))
    # int cap still accepted (protocol compatibility)
    uniform = sim.generate(2, recs, 8)
    assert all(len(TOKENIZER.encode(t)) <= 8 for t in uniform)


def test_decode_capped_never_inflates_past_cap():
    """Cutting a multi-byte UTF-8 char must not fabricate U+FFFD (3 bytes on
    re-encode): truncated texts stay within the token cap even for
    non-ASCII content."""
    for text, cap in [("café au lait", 4), ("中日한", 4), ("naïve", 3),
                      ("🎉party", 2), ("plain ascii", 5)]:
        ids = TOKENIZER.encode(text)
        capped = TOKENIZER.decode_capped(ids, cap)
        assert len(TOKENIZER.encode(capped)) <= cap, (text, cap, capped)
        assert "�" not in capped
        # naive truncate-and-decode overflows for the café case — the bug
    assert len(TOKENIZER.encode(TOKENIZER.decode(TOKENIZER.encode("café")[:4]))) > 4


# ---------------------------------------------------------------------------
# Padding invariance: a request's tokens are independent of rung/position
# ---------------------------------------------------------------------------

_PAD_INV_CACHE = {}


def _pad_inv_dispatcher() -> DecoderGenerateDispatcher:
    # built lazily (not a fixture: the hypothesis shim's @given wraps the
    # test into a zero-arg runner), shared across examples so each bucket
    # compiles exactly once
    if "d" not in _PAD_INV_CACHE:
        cfg = configs.get("smollm-360m").reduced(dtype="float32")
        model = build_model(cfg)
        _PAD_INV_CACHE["d"] = DecoderGenerateDispatcher(
            model, model.init(jax.random.key(2)))
    return _PAD_INV_CACHE["d"]


@settings(max_examples=8, deadline=None)
@given(
    extra=st.integers(0, 4),  # batch sizes 1..5 -> bucket rungs 1, 2, 4, 8
    pos=st.integers(0, 4),
    seed=st.integers(0, 2**20),
)
def test_bucketed_dispatch_padding_invariant(extra, pos, seed):
    """A row's generated tokens are identical regardless of which bucket
    rung the batch pads to and which batch position the row occupies:
    batch-of-k at position p == batch-of-1, across rungs."""
    dispatch = _pad_inv_dispatcher()
    rng = np.random.default_rng(seed)
    words = ["alpha", "beta", "gamma", "delta", "echo", "fox", "golf", "hotel"]
    queries = [" ".join(rng.choice(words, size=rng.integers(1, 4)))
               for _ in range(extra + 1)]
    pos = min(pos, extra)
    prompts = TOKENIZER.pad_batch(
        [TOKENIZER.encode(q, bos=True) for q in queries], 16)
    full = dispatch(prompts, max_new=6)
    solo = dispatch(prompts[pos:pos + 1], max_new=6)
    np.testing.assert_array_equal(full[pos], solo[0])


# ---------------------------------------------------------------------------
# Bitmask knapsack (satellite: exact selection equivalence + memory bound)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 48),  # past 32 exercises the multi-word (W=2) mask path
    budget=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitmask_knapsack_selection_matches_reference(n, budget, seed):
    """Selections (not just values) match Algorithm 1 exactly, including the
    ties-keep-not-taken backtrack rule — integer profits force ties."""
    rng = np.random.default_rng(seed)
    profits = rng.integers(1, 5, (1, n)).astype(np.float32)
    costs = rng.integers(1, budget + 8, (1, n)).astype(np.int32)
    sel = np.asarray(knapsack_select(jnp.asarray(profits), jnp.asarray(costs), budget))[0]
    ref = knapsack_reference(
        [{"cost": int(costs[0, i]), "target_score": float(profits[0, i]), "i": i}
         for i in range(n)], budget)
    ref_mask = np.zeros(n, bool)
    ref_mask[[m["i"] for m in ref]] = True
    np.testing.assert_array_equal(sel, ref_mask)


def test_bitmask_knapsack_allocates_no_take_tensor():
    """Peak live state is O(Q·(B+1)) DP+bitmask rows: no intermediate in the
    jaxpr has the [N, Q, B+1] (or [Q, N, B+1]) take-tensor shape."""
    q, n, budget = 4, 12, 48
    bp1 = budget + 1
    jaxpr = jax.make_jaxpr(
        lambda p, c: knapsack_select(p, c, budget)
    )(jnp.zeros((q, n), jnp.float32), jnp.ones((q, n), jnp.int32))
    forbidden = {(n, q, bp1), (q, n, bp1)}

    def walk(jxp):
        for eqn in jxp.eqns:
            for var in eqn.outvars:
                assert tuple(var.aval.shape) not in forbidden, (
                    f"take tensor materialized: {var.aval.shape}")
            for sub in jax.core.jaxprs_in_params(eqn.params):
                walk(sub)

    walk(jaxpr.jaxpr)
