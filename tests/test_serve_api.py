"""Request-level serving API: policy registry, scheduler, backends."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import (
    EpsilonConstraint,
    PolicyRegistry,
    SelectionPolicy,
    available_policies,
    build_predictor,
    make_policy,
    realized_cost_fraction,
    select_under_budget,
)
from repro.data import DEFAULT_POOL, generate_dataset
from repro.models import build_model
from repro.serve import (
    EnsembleRequest,
    EnsembleServer,
    LiveLMBackend,
    LiveMember,
    MemberBackend,
    Scheduler,
    SimBackend,
    requests_from_records,
)


@pytest.fixture(scope="module")
def stack():
    pred = build_predictor(num_models=len(DEFAULT_POOL))
    pp = pred.init(jax.random.key(0))
    fuser = build_model(configs.get("gen-fuser"))
    fp = fuser.init(jax.random.key(1))
    return pred, pp, fuser, fp


def _toy():
    rng = np.random.default_rng(0)
    quality = jnp.asarray(rng.uniform(-4, -2, (6, 8)), jnp.float32)
    costs = jnp.asarray(rng.uniform(1e11, 5e12, (6, 8)), jnp.float32)
    return quality, costs


# ---------------------------------------------------------------------------
# Policy registry
# ---------------------------------------------------------------------------


def test_registry_round_trips_every_builtin():
    quality, costs = _toy()
    assert available_policies()  # non-empty
    for name in available_policies():
        policy = make_policy(name)
        assert isinstance(policy, SelectionPolicy)
        assert policy.name == name
        mask = np.asarray(policy.select(quality, costs))
        assert mask.shape == quality.shape and mask.dtype == bool
        assert mask.sum(axis=1).min() >= 1  # every query gets an answer


def test_registry_budget_kwarg_uniform():
    """Every factory tolerates a budget override; budget policies obey it."""
    quality, costs = _toy()
    for name in available_policies():
        policy = make_policy(name, budget=0.3)
        assert isinstance(policy, SelectionPolicy)
    tight = make_policy("modi", budget=0.05).select(quality, costs)
    loose = make_policy("modi", budget=1.0).select(quality, costs)
    assert np.asarray(tight).sum() < np.asarray(loose).sum()
    assert bool(jnp.all(realized_cost_fraction(loose, costs) <= 1.0 + 1e-6))


def test_registry_unknown_name_and_duplicates():
    with pytest.raises(KeyError):
        make_policy("no-such-policy")
    reg = PolicyRegistry()
    reg.register("x", lambda: None)
    with pytest.raises(ValueError):
        reg.register("x", lambda: None)


def test_registry_eps_passthrough():
    policy = make_policy("modi", eps=EpsilonConstraint(0.4, buckets=64))
    assert policy.eps.fraction == 0.4 and policy.eps.buckets == 64


# ---------------------------------------------------------------------------
# Degenerate-cost guards
# ---------------------------------------------------------------------------


def test_zero_cost_rows_do_not_nan():
    quality = jnp.asarray(np.random.default_rng(0).uniform(-4, -2, (3, 4)), jnp.float32)
    costs = jnp.zeros((3, 4), jnp.float32)
    mask = select_under_budget(quality, costs, EpsilonConstraint(0.2))
    assert not bool(jnp.any(jnp.isnan(mask.astype(jnp.float32))))
    frac = realized_cost_fraction(mask, costs)
    assert bool(jnp.all(frac == 0.0))


def test_random_policy_exactly_k_and_batch_invariant():
    quality, costs = _toy()
    mask = np.asarray(make_policy("random", k=3).select(quality, costs))
    assert (mask.sum(axis=1) == 3).all()
    # independent draws per query
    assert len({tuple(row) for row in mask}) > 1
    # a query's draw does not depend on its admission-batch position
    solo = np.asarray(make_policy("random", k=3).select(quality[2:3], costs[2:3]))
    assert (solo[0] == mask[2]).all()


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


def test_backends_satisfy_protocol():
    sim = SimBackend(DEFAULT_POOL)
    assert isinstance(sim, MemberBackend)
    cfg = configs.get("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg)
    live = LiveLMBackend([LiveMember(DEFAULT_POOL[0], model, model.init(jax.random.key(0)))])
    assert isinstance(live, MemberBackend)
    assert sim.num_members() == len(DEFAULT_POOL) and live.num_members() == 1

    recs = generate_dataset(3, seed=7)
    sim_out = sim.generate(0, recs, max_new_tokens=16)
    live_out = live.generate(0, recs, max_new_tokens=8)
    assert len(sim_out) == len(live_out) == 3
    assert all(isinstance(t, str) for t in sim_out + live_out)


def test_sim_backend_deterministic_per_query():
    """Responses depend on (seed, member, query), not batch composition."""
    sim = SimBackend(DEFAULT_POOL, seed=3)
    recs = generate_dataset(5, seed=9)
    full = sim.generate(2, recs, max_new_tokens=16)
    singles = [sim.generate(2, [r], max_new_tokens=16)[0] for r in recs]
    assert full == singles


# ---------------------------------------------------------------------------
# Scheduler vs batch path
# ---------------------------------------------------------------------------


def test_scheduler_matches_batch_serve(stack):
    pred, pp, fuser, fp = stack
    recs = generate_dataset(6, seed=3)
    server = EnsembleServer(DEFAULT_POOL, make_policy("modi", budget=0.2),
                            pred, pp, fuser, fp)
    batch = server.serve(recs)

    server2 = EnsembleServer(DEFAULT_POOL, make_policy("modi", budget=0.2),
                             pred, pp, fuser, fp)
    sched = Scheduler(server2, max_batch_size=2, max_wait_ticks=2)
    futures = [sched.submit(req) for req in requests_from_records(recs)]
    assert sched.pending <= 1  # full micro-batches dispatched inline
    sched.flush()
    out = [f.result() for f in futures]
    assert [r.text for r in out] == batch.responses
    assert all((r.mask == batch.mask[i]).all() for i, r in enumerate(out))
    assert all(f.done() for f in futures)
    assert sched.stats["dispatched_requests"] == 6


def test_scheduler_tick_and_result_force_dispatch(stack):
    pred, pp, fuser, fp = stack
    recs = generate_dataset(3, seed=5)
    server = EnsembleServer(DEFAULT_POOL, make_policy("best-single"), pred, pp, fuser, fp)
    sched = Scheduler(server, max_batch_size=8, max_wait_ticks=2)
    f0 = sched.submit(requests_from_records(recs)[0])
    assert not f0.done() and sched.pending == 1
    assert sched.tick() == 0  # age 1 < max_wait_ticks
    assert sched.tick() == 1  # aged out -> dispatched
    assert f0.done()
    f1 = sched.submit(requests_from_records(recs)[1])
    r1 = f1.result()  # forces a flush of the still-queued request
    assert r1.text == f1.result().text and sched.pending == 0
    assert r1.policy_name == "best-single"
    assert set(r1.timing) == {"predict_s", "select_s", "generate_s", "fuse_s", "total_s"}


def test_per_request_budget_and_policy_override(stack):
    pred, pp, fuser, fp = stack
    rec = generate_dataset(1, seed=11)[0]
    server = EnsembleServer(DEFAULT_POOL, make_policy("modi", budget=0.2),
                            pred, pp, fuser, fp)
    tight, loose, blender = server.serve_requests([
        EnsembleRequest(query=rec.query, record=rec, budget=0.15),
        EnsembleRequest(query=rec.query, record=rec, budget=1.0),
        EnsembleRequest(query=rec.query, record=rec, policy="llm-blender"),
    ])
    assert tight.mask.sum() < loose.mask.sum()
    assert tight.cost_fraction <= 0.15 + 1e-6
    assert blender.mask.all() and blender.policy_name == "llm-blender"
    # member texts present exactly where selected; costs accounted
    for resp in (tight, loose, blender):
        for j in range(len(DEFAULT_POOL)):
            assert (resp.member_texts[j] is not None) == bool(resp.mask[j])
        assert resp.realized_cost >= 0.0


def test_budget_override_preserves_default_policy_kwargs(stack):
    """A budget-only override must not reset the configured policy's other
    constructor kwargs to registry defaults."""
    pred, pp, fuser, fp = stack
    rec = generate_dataset(1, seed=13)[0]
    server = EnsembleServer(
        DEFAULT_POOL, make_policy("hybrid-router", small_index=7, large_index=1),
        pred, pp, fuser, fp,
    )
    resp = server.serve_requests(
        [EnsembleRequest(query=rec.query, record=rec, budget=0.5)]
    )[0]
    assert set(np.flatnonzero(resp.mask).tolist()) <= {1, 7}
    # and for a budget policy the override actually moves the constraint
    server2 = EnsembleServer(DEFAULT_POOL, make_policy("modi", buckets=64),
                             pred, pp, fuser, fp)
    key = server2._policy_key(EnsembleRequest(query="q", budget=0.4))
    policy = server2._build_policy(key)
    assert policy.eps.fraction == 0.4 and policy.eps.buckets == 64


def test_max_new_tokens_enforced_and_batch_invariant(stack):
    """The per-request cap applies to member texts even for the row holding
    the group max, so texts cannot depend on micro-batch composition."""
    pred, pp, fuser, fp = stack
    rec = generate_dataset(1, seed=17)[0]
    server = EnsembleServer(DEFAULT_POOL, make_policy("llm-blender"),
                            pred, pp, fuser, fp)
    solo = server.serve_requests(
        [EnsembleRequest(query=rec.query, record=rec, max_new_tokens=4)]
    )[0]
    mixed = server.serve_requests([
        EnsembleRequest(query=rec.query, record=rec, max_new_tokens=4),
        EnsembleRequest(query=rec.query, record=rec, max_new_tokens=32),
    ])[0]
    assert solo.member_texts == mixed.member_texts
    assert all(t is None or len(t.encode()) <= 4 for t in solo.member_texts)


def test_scheduler_rejects_malformed_requests_at_submit(stack):
    pred, pp, fuser, fp = stack
    server = EnsembleServer(DEFAULT_POOL, make_policy("best-single"), pred, pp, fuser, fp)
    sched = Scheduler(server, max_batch_size=8)
    with pytest.raises(KeyError):
        sched.submit(EnsembleRequest(query="q", policy="typo"))
    with pytest.raises(TypeError):
        sched.submit(EnsembleRequest(query="q", policy_kwargs={"bogus_field": 1}))
    assert sched.pending == 0  # rejected before enqueueing


def test_scheduler_dispatch_failure_fails_every_future(stack, monkeypatch):
    """An engine-side crash must resolve all sibling futures with the cause
    rather than leaving them pending forever."""
    pred, pp, fuser, fp = stack
    recs = generate_dataset(2, seed=19)
    server = EnsembleServer(DEFAULT_POOL, make_policy("best-single"), pred, pp, fuser, fp)
    sched = Scheduler(server, max_batch_size=8)
    futures = [sched.submit(req) for req in requests_from_records(recs)]

    def boom(requests):
        raise RuntimeError("engine crashed")

    monkeypatch.setattr(server, "serve_requests", boom)
    with pytest.raises(RuntimeError):
        sched.flush()
    assert all(f.done() for f in futures)
    for f in futures:
        with pytest.raises(RuntimeError):
            f.result()


def test_backend_pool_size_mismatch_rejected(stack):
    pred, pp, fuser, fp = stack
    with pytest.raises(ValueError):
        EnsembleServer(DEFAULT_POOL, make_policy("best-single"), pred, pp, fuser, fp,
                       backend=SimBackend(DEFAULT_POOL[:3]))


# ---------------------------------------------------------------------------
# Continuous batching: result() scope, policy-group batching, rung snapping
# ---------------------------------------------------------------------------


def test_result_dispatches_only_own_batch(stack):
    """Regression: ``result()`` used to flush the ENTIRE queue, force-
    dispatching other submitters' young requests.  It must dispatch only
    the batches up to and including the one containing this future —
    other policy groups stay queued for their own triggers."""
    pred, pp, fuser, fp = stack
    server = EnsembleServer(DEFAULT_POOL, make_policy("modi", budget=0.2),
                            pred, pp, fuser, fp)
    sched = Scheduler(server, max_batch_size=8, max_wait_ticks=10)
    recs = generate_dataset(3, seed=23)
    mine = sched.submit(EnsembleRequest(query=recs[0].query, record=recs[0]))
    other = sched.submit(EnsembleRequest(query=recs[1].query, record=recs[1],
                                         policy="best-single"))
    mine.result()
    assert mine.done() and not other.done()
    assert sched.pending == 1  # the other group was NOT force-flushed

    # same-group requests ahead of the target ride along; younger ones wait
    sched2 = Scheduler(server, max_batch_size=2, max_wait_ticks=10)
    futs = [sched2.submit(EnsembleRequest(query=r.query, record=r))
            for r in recs]
    futs[2].result()
    assert all(f.done() for f in futs)  # [0,1] then [2]: two batches
    assert sched2.stats["dispatched_batches"] == 2


def test_inline_dispatch_is_per_policy_group(stack):
    """max_batch_size counts one policy group, not the whole queue: two
    half-full groups must not be spliced into one mixed batch."""
    pred, pp, fuser, fp = stack
    server = EnsembleServer(DEFAULT_POOL, make_policy("modi", budget=0.2),
                            pred, pp, fuser, fp)
    sched = Scheduler(server, max_batch_size=3, max_wait_ticks=10)
    recs = generate_dataset(4, seed=29)
    for i, rec in enumerate(recs):
        sched.submit(EnsembleRequest(
            query=rec.query, record=rec,
            policy=None if i % 2 == 0 else "best-single"))
    assert sched.pending == 4  # 2 + 2, neither group reached 3
    sched.submit(EnsembleRequest(query=recs[0].query, record=recs[0]))
    assert sched.pending == 2  # default group hit 3 and dispatched alone
    assert sched.stats["dispatched_batches"] == 1


def test_tick_snaps_batch_to_ladder_rung(stack):
    """An aged-out head drags the group along, but only down to the
    largest bucket rung: 3 urgent + 2 young candidates -> a batch of 4
    (floor rung of 5), leaving the youngest queued."""
    pred, pp, fuser, fp = stack
    server = EnsembleServer(DEFAULT_POOL, make_policy("modi", budget=0.2),
                            pred, pp, fuser, fp)
    sched = Scheduler(server, max_batch_size=8, max_wait_ticks=2)
    recs = generate_dataset(5, seed=31)
    for rec in recs[:3]:
        sched.submit(EnsembleRequest(query=rec.query, record=rec))
    sched.tick()  # ages 3 -> 1
    for rec in recs[3:]:
        sched.submit(EnsembleRequest(query=rec.query, record=rec))
    served = sched.tick()  # first three hit max_wait_ticks=2
    assert served == 4  # rung snap: 5 available -> rung 4
    assert sched.pending == 1
    assert sched.stats["padded_rows"] == 0  # 4 is exactly a rung


def test_member_failure_hedges_to_survivors(stack, monkeypatch):
    """A backend crash on one member re-serves the batch with that member
    excluded instead of failing every sibling future."""
    pred, pp, fuser, fp = stack
    server = EnsembleServer(DEFAULT_POOL, make_policy("llm-blender"),
                            pred, pp, fuser, fp)
    sched = Scheduler(server, max_batch_size=4, max_wait_ticks=2)
    recs = generate_dataset(2, seed=37)

    real = server.backend.generate
    calls = {"n": 0}

    def flaky(member_idx, records, max_new_tokens):
        if member_idx == 1 and calls["n"] == 0:
            calls["n"] += 1
            raise RuntimeError("member 1 transiently down")
        return real(member_idx, records, max_new_tokens)

    monkeypatch.setattr(server.backend, "generate", flaky)
    futures = [sched.submit(req) for req in requests_from_records(recs)]
    sched.flush()
    out = [f.result() for f in futures]
    assert sched.stats["hedges"] == 1
    # the hedged batch equals the offline path with the member excluded
    offline = server.serve_requests(requests_from_records(recs),
                                    exclude_members=frozenset({1}))
    for resp, off in zip(out, offline):
        assert not resp.mask[1]  # the failed member was excluded
        assert resp.text == off.text
        assert resp.member_texts == off.member_texts
