"""Cluster-serving suite: placement, async dispatch, host-failure hedging.

Runs in the scenario tier (``-m scenario``) and, additionally, as the CI
``cluster`` job (``-m cluster``) under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the per-host
mesh path is exercised on real (forced) multi-device CPU.  Everything
here also passes on one device — placement then runs logical-only with
identical routing.

Pinned properties:

* **sync/async byte-equivalence** — every preset scenario's async trace
  (and responses) is byte-identical to its ``sync=True`` trace;
* **host-failure determinism** — the host-outage re-serve is exactly
  replayable, and its responses equal the offline engine path with the
  dead members masked (knapsack re-solved over the survivors);
* **placement invariance** — routing a batch through *any* member→host
  assignment yields identical fused outputs (property test);
* **deadline-aware admission** — the predicted-queue-delay shed follows
  a hand-computed golden trace;
* **wall-clock capture/replay** — a captured run re-drives a fresh
  scheduler to byte-identical responses.
"""

import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs
from repro.core import build_predictor, make_policy
from repro.data import DEFAULT_POOL, generate_dataset, query_cost_matrix
from repro.models import build_model
from repro.serve import (
    AdmissionControl,
    CancelledShard,
    ClusterRouter,
    DispatchWorker,
    EnsembleRequest,
    EnsembleServer,
    HealthMonitor,
    HostExecutorPool,
    HostFailure,
    InboxFull,
    PlacementPlan,
    RequestShed,
    Scheduler,
    TrafficSimulator,
    preset_scenarios,
    requests_from_records,
)

pytestmark = [pytest.mark.scenario, pytest.mark.cluster]

N_POOL = len(DEFAULT_POOL)
RECORDS = generate_dataset(12, seed=3)


@pytest.fixture(scope="module")
def stack():
    pred = build_predictor(num_models=N_POOL)
    pp = pred.init(jax.random.key(0))
    fuser = build_model(configs.get("gen-fuser"))
    fp = fuser.init(jax.random.key(1))
    return pred, pp, fuser, fp


def _server(stack, policy="modi", **kwargs):
    pred, pp, fuser, fp = stack
    return EnsembleServer(DEFAULT_POOL, make_policy(policy, **kwargs),
                          pred, pp, fuser, fp)


def _sched(stack, sync=True, **kwargs):
    kwargs.setdefault("max_batch_size", 4)
    kwargs.setdefault("max_wait_ticks", 2)
    return Scheduler(_server(stack, budget=0.2), sync=sync, **kwargs)


# ---------------------------------------------------------------------------
# PlacementPlan
# ---------------------------------------------------------------------------


def test_auto_placement_balances_and_covers():
    plan = PlacementPlan.auto(DEFAULT_POOL, n_hosts=4)
    placed = sorted(j for h in range(4) for j in plan.members_on_host(h))
    assert placed == list(range(N_POOL))  # every member placed exactly once
    load = plan.host_load()
    # greedy balance: no host carries more than ~2x the lightest
    assert max(load.values()) <= 2 * min(load.values())


def test_auto_placement_replicas_on_distinct_hosts():
    plan = PlacementPlan.auto(DEFAULT_POOL, n_hosts=4, replicas=2)
    for p in plan.placements:
        assert len(set(p.hosts)) == 2
    # one host down: every member keeps a replica
    assert plan.mark_host_dead(0) == []
    assert plan.dead_members() == []


def test_mark_host_dead_reports_newly_unroutable_members():
    plan = PlacementPlan.round_robin(N_POOL, 4)
    lost = plan.mark_host_dead(1)
    assert lost == [j for j in range(N_POOL) if j % 4 == 1]
    assert plan.primary_host(lost[0]) is None
    assert sorted(plan.alive_members() + lost) == list(range(N_POOL))
    plan.revive()
    assert plan.dead_members() == []


def test_placement_plan_validates():
    with pytest.raises(ValueError):
        PlacementPlan.auto(DEFAULT_POOL, n_hosts=0)
    with pytest.raises(ValueError):
        PlacementPlan.auto(DEFAULT_POOL, n_hosts=2, replicas=3)


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 forced host devices (CI cluster job)")
def test_placement_builds_real_host_meshes():
    devices = jax.devices()[:8]
    plan = PlacementPlan.auto(DEFAULT_POOL, n_hosts=4, devices=devices)
    for h in range(4):
        mesh = plan.host_mesh(h)
        assert mesh is not None and mesh.devices.size == 2
    rules = plan.member_rules(0)
    assert rules is not None and rules.mesh.axis_names == ("data", "model")


# ---------------------------------------------------------------------------
# Sync/async byte-equivalence on every preset scenario
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(preset_scenarios()))
def test_async_trace_matches_sync_trace(stack, name):
    scenario = preset_scenarios(n_requests=12)[name]
    sync_rep = TrafficSimulator(_sched(stack, sync=True), scenario,
                                RECORDS).run()
    sched = _sched(stack, sync=False)
    try:
        async_rep = TrafficSimulator(sched, scenario, RECORDS).run()
    finally:
        sched.close()
    assert async_rep.trace == sync_rep.trace
    assert async_rep.stats == sync_rep.stats
    assert ([r.text if r else None for r in async_rep.responses]
            == [r.text if r else None for r in sync_rep.responses])
    assert async_rep.latency_ticks == sync_rep.latency_ticks


def test_async_submit_returns_before_batch_serves(stack):
    """A full policy group enqueues its batch; submit must come back with
    the batch still unserved (the worker picks it up afterwards)."""
    sched = _sched(stack, sync=False, max_batch_size=2, max_wait_ticks=10)
    try:
        blocker = threading.Event()
        inner = sched.server.backend
        orig = inner.generate

        def slow_generate(j, records, caps):
            blocker.wait(10.0)
            return orig(j, records, caps)

        inner.generate = slow_generate
        futs = [sched.submit(EnsembleRequest(query=r.query, record=r))
                for r in RECORDS[:2]]
        # inline trigger fired (queue drained) but service is blocked
        assert sched.pending == 0
        assert not any(f.done() for f in futs)
        blocker.set()
        sched.join()
        assert all(f.done() for f in futs)
    finally:
        sched.close()


def test_async_engine_error_surfaces_at_result(stack):
    sched = Scheduler(_server(stack, budget=0.2), max_batch_size=2,
                      max_wait_ticks=10, sync=False, hedge=False)
    try:
        inner = sched.server.backend

        def boom(j, records, caps):
            raise RuntimeError("backend down")

        inner.generate = boom
        futs = [sched.submit(EnsembleRequest(query=r.query, record=r))
                for r in RECORDS[:2]]
        sched.join()
        for f in futs:
            with pytest.raises(RuntimeError, match="backend down"):
                f.result(timeout=5.0)
    finally:
        sched.close()


def test_dispatch_worker_backpressure():
    started = threading.Event()
    release = threading.Event()

    def slow(job):
        started.set()
        release.wait(10.0)

    w = DispatchWorker(slow, capacity=1)
    try:
        w.submit("a")
        assert started.wait(5.0)
        w.submit("b")  # fills the inbox while "a" is in service
        with pytest.raises(InboxFull):
            w.try_submit("c")
        assert w.full()
        release.set()
        w.join()
        assert w.processed == 2
    finally:
        release.set()
        w.close()


# ---------------------------------------------------------------------------
# Worker lifecycle: submit/close races, executor pool, shard cancellation
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_try_submit_vs_close_interleaving_never_strands_jobs(seed):
    """Race ``try_submit`` against ``close()`` under random
    interleavings: a job the worker ACCEPTED (try_submit returned
    without raising) must always end up either processed or handed to
    ``on_orphan`` — never silently dropped into a closed inbox, never a
    hung future — and submits after close fail loudly."""
    rng = np.random.default_rng(seed)
    pre_delays = rng.random(8) * 1e-3
    close_delay = float(rng.random()) * 2e-3
    served, orphans, accepted = [], [], []
    w = DispatchWorker(served.append, capacity=4, on_orphan=orphans.append)
    start = threading.Barrier(2)

    def produce():
        start.wait()
        for i, d in enumerate(pre_delays):
            try:
                w.try_submit(i)
            except (InboxFull, RuntimeError):
                continue  # backpressure or closed: the caller was told
            accepted.append(i)
            time.sleep(d)

    t = threading.Thread(target=produce)
    t.start()
    start.wait()
    time.sleep(close_delay)
    w.close()
    t.join(5.0)
    assert not t.is_alive()
    # every accepted job is accounted for exactly once
    assert sorted(served + orphans) == sorted(accepted)
    assert w.orphaned == len(orphans)
    with pytest.raises(RuntimeError, match="closed"):
        w.submit("late")
    with pytest.raises(RuntimeError, match="closed"):
        w.try_submit("late")


def test_host_executor_pool_close_is_idempotent_and_final():
    pool = HostExecutorPool(capacity=2)
    f = pool.submit(0, lambda: 41 + 1)
    assert f.result(timeout=5.0) == 42
    assert pool.spawned == 1
    pool.close()
    pool.close()  # idempotent: second close is a no-op, not an error
    assert pool.closed
    # a post-close submit must refuse loudly instead of lazily respawning
    # an executor thread nothing will ever join
    with pytest.raises(RuntimeError, match="closed"):
        pool.submit(0, lambda: None)
    assert pool.spawned == 1  # the rejected submit respawned nothing
    assert pool.live_hosts() == []


def test_shard_future_cancellation_semantics():
    pool = HostExecutorPool(capacity=4)
    try:
        release = threading.Event()
        blocker = pool.submit(0, lambda: (release.wait(10.0), "first")[1])
        queued = pool.submit(0, lambda: "ran")
        assert queued.cancel()  # still queued behind the blocker
        assert queued.cancelled()
        release.set()
        with pytest.raises(CancelledShard):
            queued.result(timeout=5.0)
        assert blocker.result(timeout=5.0) == "first"
        assert not blocker.cancel()  # already resolved: cancel refuses
    finally:
        pool.close()


def test_result_timeout_records_event_and_stays_resolvable(stack):
    """result(timeout=) expiring while the batch is in flight raises
    TimeoutError, leaves a "timeout" trace event (an abandoned wait used
    to be silent), and keeps the future resolvable: a later result()
    returns normally once the batch lands."""
    sched = _sched(stack, sync=False, max_batch_size=2, max_wait_ticks=10)
    release = threading.Event()
    try:
        inner = sched.server.backend
        orig = inner.generate

        def slow_generate(j, records, caps):
            release.wait(10.0)
            return orig(j, records, caps)

        inner.generate = slow_generate
        futs = [sched.submit(EnsembleRequest(query=r.query, record=r))
                for r in RECORDS[:2]]
        with pytest.raises(TimeoutError, match="not served within"):
            futs[0].result(timeout=0.05)
        timeouts = [e for e in sched.events if e["event"] == "timeout"]
        assert len(timeouts) == 1
        assert timeouts[0]["req"] == 0 and timeouts[0]["waited_s"] == 0.05
        assert sched.stats["result_timeouts"] == 1
        release.set()
        sched.join()
        assert futs[0].result(timeout=5.0).text  # still resolvable
        assert futs[1].result(timeout=5.0).text
    finally:
        release.set()
        sched.close()


def test_health_monitor_backoff_probation_and_flaky_probe():
    """Breaker mechanics in isolation: two consecutive probe failures
    open host 0 (members stranded), failed half-open probes back off
    exponentially (2 → 4 → capped 4), and the first clean probe after
    the underlying health returns revives it.  A single flaky probe on
    host 1 stays under the threshold and never opens anything."""
    plan = PlacementPlan.round_robin(N_POOL, 2)
    hm = HealthMonitor(plan, probe_interval=1, probe_failures=2,
                       probe_faults={0: (0, 1, 2, 3, 4), 1: (2,)},
                       recovery={0: (1,)}, backoff_ticks=2, backoff_cap=4)
    trace = []
    for now in range(1, 15):
        trace.extend((now, ev) for ev in hm.run_probes(now))

    deaths = [(t, e) for t, e in trace if e["event"] == "probe_death"]
    assert deaths == [(2, {"event": "probe_death", "host": 0, "failures": 2,
                           "stranded": [0, 2, 4, 6]})]
    half_open = [(t, e["ok"]) for t, e in trace
                 if e["event"] == "probe" and e["half_open"]]
    assert half_open == [(4, False), (6, False), (10, False), (14, True)]
    revives = [(t, e) for t, e in trace if e["event"] == "probe_revive"]
    assert revives == [(14, {"event": "probe_revive", "host": 0,
                             "recovered": [0, 2, 4, 6], "after_probes": 6})]
    assert plan.dead_hosts == set()
    assert hm.state(0) == "closed"
    # host 1's isolated flaky probe: trace-visible, below threshold
    flaky = [(t, e["probe"]) for t, e in trace
             if e.get("host") == 1 and e["event"] == "probe" and not e["ok"]]
    assert flaky == [(3, 2)]
    assert not any(e["event"] == "probe_death" and e["host"] == 1
                   for _, e in trace)


# ---------------------------------------------------------------------------
# Host failure: hedging, masked knapsack re-solve, determinism
# ---------------------------------------------------------------------------


def test_host_outage_reserves_on_survivors_and_masks_knapsack(stack):
    scenario = preset_scenarios(n_requests=12)["host-outage"]
    sched = _sched(stack)
    report = TrafficSimulator(sched, scenario, RECORDS).run()
    assert report.served == report.n
    assert report.stats["host_hedges"] == 1

    hedge = next(e for e in report.trace if e["event"] == "host_hedge")
    dead = set(hedge["members"])
    assert dead  # the outage actually killed unreplicated members
    router = sched.server.backend
    assert isinstance(router, ClusterRouter)
    assert set(router.dead_members()) == dead

    # every response after the fault selects no dead member
    hedged_and_later = [i for i in range(report.n) if i >= min(hedge["reqs"])]
    for i in hedged_and_later:
        assert not report.responses[i].mask[sorted(dead)].any()

    # the hedged batch equals the offline path with the dead members
    # masked (knapsack re-solved over survivors, not post-hoc excluded)
    offline = _server(stack, budget=0.2).serve_requests(
        [report.requests[i] for i in hedge["reqs"]],
        masked_members=frozenset(dead))
    for i, resp in zip(hedge["reqs"], offline):
        assert report.responses[i].text == resp.text
        assert (report.responses[i].mask == resp.mask).all()

    # requests fully served before the fault match the plain offline path
    before = [i for i in range(report.n) if i < min(hedge["reqs"])]
    plain = _server(stack, budget=0.2).serve_requests(
        [report.requests[i] for i in before])
    for i, resp in zip(before, plain):
        assert report.responses[i].text == resp.text


def test_host_outage_trace_replays_identically(stack):
    scenario = preset_scenarios(n_requests=12)["host-outage"]

    def run_once():
        return TrafficSimulator(_sched(stack), scenario, RECORDS).run()

    a, b = run_once(), run_once()
    assert a.trace == b.trace
    assert a.stats == b.stats


def test_replicated_placement_absorbs_host_death(stack):
    """With replicas=2 every member survives one host's death: the router
    fails over internally, no HostFailure escapes, no knapsack re-solve.
    llm-blender selects every member, so some generation is guaranteed to
    route to the failing host and trip the injection."""
    server = _server(stack, policy="llm-blender")
    plan = PlacementPlan.auto(DEFAULT_POOL, n_hosts=4, replicas=2)
    server.backend = ClusterRouter(server.backend, plan=plan,
                                   host_failures={0: (0,)})
    sched = Scheduler(server, max_batch_size=4, max_wait_ticks=2)
    futs = [sched.submit(EnsembleRequest(query=r.query, record=r))
            for r in RECORDS[:8]]
    sched.flush()
    texts = [f.result().text for f in futs]
    assert sched.stats["host_hedges"] == 0
    assert server.backend.stats["failovers"] >= 1
    baseline = _server(stack, policy="llm-blender").serve_requests(
        requests_from_records(RECORDS[:8]))
    assert texts == [r.text for r in baseline]


def test_total_outage_fails_batch_but_resolves_futures(stack):
    """Every host dying leaves nothing to hedge onto: the batch fails,
    futures resolve with the cause (never hang) — and batches formed
    AFTER the total outage fail with a clear error rather than handing
    the engine an empty pool (regression: they used to die on an
    IndexError deep in selection)."""
    server = _server(stack, budget=0.2)
    plan = PlacementPlan.round_robin(N_POOL, 2)
    server.backend = ClusterRouter(server.backend, plan=plan,
                                   host_failures={0: (0, 1, 2, 3),
                                                  1: (0, 1, 2, 3)})
    sched = Scheduler(server, max_batch_size=2, max_wait_ticks=10)
    futs = []
    with pytest.raises(HostFailure):
        for r in RECORDS[:2]:
            futs.append(sched.submit(EnsembleRequest(query=r.query, record=r)))
    assert sched.last_submitted is not None and sched.last_submitted.done()
    with pytest.raises(HostFailure):
        sched.last_submitted.result()

    late = []
    with pytest.raises(RuntimeError, match="no servable pool members"):
        for r in RECORDS[2:4]:
            late.append(sched.submit(EnsembleRequest(query=r.query, record=r)))
    assert sched.last_submitted.done()
    with pytest.raises(RuntimeError, match="no servable pool members"):
        sched.last_submitted.result()


def test_async_result_after_close_resolves_instead_of_hanging(stack):
    """Regression: result() on a queued request after close() used to pop
    the batch, fail the worker submit, and leave every future pending
    forever.  It must resolve the futures with the closed-worker cause."""
    sched = _sched(stack, sync=False, max_batch_size=8, max_wait_ticks=10)
    f1 = sched.submit(EnsembleRequest(query=RECORDS[0].query,
                                      record=RECORDS[0]))
    f2 = sched.submit(EnsembleRequest(query=RECORDS[1].query,
                                      record=RECORDS[1]))
    sched.close()
    with pytest.raises(RuntimeError, match="closed"):
        f1.result(timeout=5.0)
    assert f2.done()
    with pytest.raises(RuntimeError, match="closed"):
        f2.result(timeout=5.0)


def test_engine_masked_members_resolve_knapsack_over_survivors(stack):
    """masked_members re-targets ε at the survivors' full-ensemble cost —
    the policy solves over the surviving columns, not the full matrix
    with columns struck out afterwards."""
    server = _server(stack, budget=0.2)
    reqs = requests_from_records(RECORDS[:8])
    masked = frozenset({1, 7})
    via_mask = server.serve_requests(reqs, masked_members=masked)
    alive = [j for j in range(N_POOL) if j not in masked]
    for r in via_mask:
        assert not r.mask[sorted(masked)].any()
    # the engine's masked solve == the policy run on the reduced matrices
    records = [req.resolve_record() for req in reqs]
    r_hat = server.predict_quality([r.query for r in records])
    costs = query_cost_matrix(DEFAULT_POOL, records)
    reduced = np.asarray(make_policy("modi", budget=0.2).select(
        jnp.asarray(r_hat[:, alive]), jnp.asarray(costs[:, alive])))
    expect = np.zeros((len(reqs), N_POOL), bool)
    expect[:, alive] = reduced
    got = np.stack([r.mask for r in via_mask])
    assert (got == expect).all()
    # and the ε budget now binds on the survivors' full-ensemble cost
    survivors_total = costs[:, alive].sum(axis=1)
    realized = np.asarray([r.realized_cost for r in via_mask])
    single_min = costs[:, alive].min(axis=1)  # cheapest-survivor fallback floor
    assert (realized <= np.maximum(0.2 * survivors_total, single_min) + 1e-6).all()


# ---------------------------------------------------------------------------
# Placement-permutation property: routing never changes outputs
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), n_hosts=st.sampled_from([2, 3, 4, 5]))
def test_any_placement_permutation_is_output_invariant(seed, n_hosts):
    """Routing a batch through ANY member→host assignment (not just the
    balanced placer's) yields fused outputs identical to the unrouted
    engine — placement decides where generation runs, never what it says."""
    stack = _PROPERTY_STACK
    rng = np.random.default_rng(seed)
    base = PlacementPlan.round_robin(N_POOL, n_hosts)
    plan = PlacementPlan(
        hosts=base.hosts,
        placements=[
            dataclasses.replace(p, hosts=(int(rng.integers(0, n_hosts)),))
            for p in base.placements
        ],
    )
    server = _server(stack, budget=0.2)
    server.backend = ClusterRouter(server.backend, plan=plan)
    routed = server.serve_requests(requests_from_records(RECORDS[:4]))
    assert [r.text for r in routed] == _PROPERTY_BASELINE


_PROPERTY_STACK = None
_PROPERTY_BASELINE = None


@pytest.fixture(autouse=True)
def _property_stack(stack):
    """The hypothesis shim drives tests without pytest fixtures — stage the
    module stack (and the unrouted baseline) for the property test."""
    global _PROPERTY_STACK, _PROPERTY_BASELINE
    _PROPERTY_STACK = stack
    if _PROPERTY_BASELINE is None:
        _PROPERTY_BASELINE = [
            r.text for r in _server(stack, budget=0.2).serve_requests(
                requests_from_records(RECORDS[:4]))
        ]
    yield


# ---------------------------------------------------------------------------
# Deadline-aware admission: golden trace for the new shed reason
# ---------------------------------------------------------------------------


def test_deadline_aware_admission_golden_trace(stack):
    """max_batch_size=2, deadline_aware on.  Ticks are hand-computed:

    * tick 0 — two requests fill a batch and dispatch inline.  First
      dispatch seeds the gap clock only (EWMA still empty).
    * ticks 1-2 — clock advances, nothing queued.
    * tick 2 — two more requests dispatch inline: gap = 2 ticks, EWMA=2.
    * submit A (deadline_ticks=1): predicted delay = EWMA 2.0 × 1 batch
      ahead = 2.0 > 1 → shed, reason ``deadline``.
    * submit B (deadline_ticks=4): 2.0 <= 4 → admitted and queued.
    """
    sched = Scheduler(
        _server(stack, budget=0.2), max_batch_size=2, max_wait_ticks=10,
        admission=AdmissionControl(deadline_aware=True))
    recs = generate_dataset(6, seed=11)
    for r in recs[:2]:
        sched.submit(EnsembleRequest(query=r.query, record=r))
    assert sched.predicted_queue_delay() == 0.0  # no gap observed yet
    sched.tick()
    sched.tick()
    for r in recs[2:4]:
        sched.submit(EnsembleRequest(query=r.query, record=r))
    assert sched.predicted_queue_delay() == 2.0

    shed_f = sched.submit(EnsembleRequest(query=recs[4].query, record=recs[4],
                                          deadline_ticks=1))
    assert shed_f.shed()
    with pytest.raises(RequestShed, match="predicted queue delay"):
        shed_f.result()
    ok_f = sched.submit(EnsembleRequest(query=recs[5].query, record=recs[5],
                                        deadline_ticks=4))
    assert not ok_f.done() and sched.pending == 1

    assert sched.stats["shed"] == 1
    shed_events = [e for e in sched.events if e["event"] == "shed"]
    assert shed_events == [{
        "tick": 2, "event": "shed", "req": 4, "reason": "deadline",
        "predicted_delay": 2.0, "deadline_ticks": 1,
    }]
    sched.flush()
    assert ok_f.done()


def test_deadline_aware_ignores_requests_without_deadline(stack):
    sched = Scheduler(
        _server(stack, budget=0.2), max_batch_size=2, max_wait_ticks=10,
        admission=AdmissionControl(deadline_aware=True))
    recs = generate_dataset(3, seed=11)
    for r in recs[:2]:
        sched.submit(EnsembleRequest(query=r.query, record=r))
    sched.tick()
    sched.tick()
    sched.tick()
    for r in recs[:2]:
        sched.submit(EnsembleRequest(query=r.query, record=r))
    assert sched.predicted_queue_delay() == 3.0
    f = sched.submit(EnsembleRequest(query=recs[2].query, record=recs[2]))
    assert not f.shed()  # no deadline, nothing to miss
    sched.flush()


# ---------------------------------------------------------------------------
# Wall-clock capture/replay
# ---------------------------------------------------------------------------


def test_captured_trace_replays_byte_identically(stack):
    scenario = preset_scenarios(n_requests=12)["steady"]
    original = TrafficSimulator(_sched(stack), scenario, RECORDS).run()
    captured = original.captured()
    assert len(captured.wall_ns) == original.n
    assert list(captured.ticks) == original.arrival_ticks
    assert all(b >= a for a, b in zip(captured.wall_ns, captured.wall_ns[1:]))

    replayed = TrafficSimulator.replay(_sched(stack), captured)
    assert [r.text for r in replayed.responses] == [
        r.text for r in original.responses]
    assert replayed.arrival_ticks == original.arrival_ticks
    assert replayed.trace == original.trace


def test_captured_trace_time_scale_compresses_schedule(stack):
    scenario = preset_scenarios(n_requests=12)["steady"]
    captured = TrafficSimulator(_sched(stack), scenario, RECORDS).run().captured()
    fast = TrafficSimulator.replay(_sched(stack), captured, time_scale=4.0)
    assert fast.served == fast.n
    # 4x compression: the wall-derived schedule spans well under the
    # original's logical span
    assert max(fast.arrival_ticks) <= max(captured.ticks)
    # and replaying the same capture at the same scale is deterministic
    again = TrafficSimulator.replay(_sched(stack), captured, time_scale=4.0)
    assert again.arrival_ticks == fast.arrival_ticks
    assert [r.text for r in again.responses] == [r.text for r in fast.responses]


# ---------------------------------------------------------------------------
# Diurnal load curve (scenario-tier coverage for the new preset)
# ---------------------------------------------------------------------------


def test_diurnal_scenario_miss_and_shed_rates(stack):
    """The diurnal curve with a 30% urgent (deadline 0) mix stresses the
    fleet both ways: without admission the trough stragglers dispatch a
    tick late and MISS; with deadline-aware admission those same hopeless
    requests SHED at arrival instead, and served requests never miss.
    Rates are pinned to bands (not exact counts) so unrelated scheduler
    tweaks don't churn them."""
    scenario = dataclasses.replace(
        preset_scenarios(n_requests=24)["diurnal"],
        mix=((0.7, {}), (0.3, {"deadline_ticks": 0, "priority": 1})))
    records = generate_dataset(24, seed=3)

    plain = TrafficSimulator(
        Scheduler(_server(stack, budget=0.2), max_batch_size=4,
                  max_wait_ticks=2),
        scenario, records).run()
    assert plain.served == plain.n  # best-effort serves everything...
    assert 0.0 < plain.deadline_miss_rate <= 0.3  # ...but peak clumps miss

    aware = TrafficSimulator(
        Scheduler(_server(stack, budget=0.2), max_batch_size=4,
                  max_wait_ticks=2,
                  admission=AdmissionControl(deadline_aware=True)),
        scenario, records).run()
    assert aware.served + aware.stats["shed"] == aware.n  # nothing hangs
    assert 0.0 < aware.shed_rate <= 0.5  # the hopeless requests shed...
    assert aware.deadline_miss_rate == 0.0  # ...and served ones never miss


def test_diurnal_arrivals_are_deterministic_and_follow_curve():
    proc = preset_scenarios()["diurnal"].arrivals
    a = proc.arrival_ticks(48, np.random.default_rng(0))
    b = proc.arrival_ticks(48, np.random.default_rng(7))
    assert a == b  # rng-free: the curve is the schedule
    assert all(x <= y for x, y in zip(a, a[1:]))
    # arrivals clump at the peak: the busiest period-window holds more
    # than an even share
    period = proc.period
    counts = np.bincount(np.asarray(a) // period)
    assert counts.max() > len(a) / max(len(counts), 1)
