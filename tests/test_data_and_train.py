"""Data pipeline, optimizer, trainer and checkpointing tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.data import (
    DEFAULT_POOL,
    DOMAIN_NAMES,
    TOKENIZER,
    generate_dataset,
    lm_batches,
    member_response,
    predictor_batches,
    scorer_batches,
)
from repro.models import build_model
from repro.optim import AdamW, clip_by_global_norm, cosine_with_warmup
from repro.optim.adafactor import Adafactor
from repro.train import checkpoint, repeat_batches, train


# ---------------------------------------------------------------------------
# Tokenizer
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.text(max_size=80))
def test_tokenizer_roundtrip(text):
    ids = TOKENIZER.encode(text)
    assert TOKENIZER.decode(ids) == text.encode("utf-8", errors="replace").decode("utf-8", errors="replace")
    assert all(0 <= i < 256 for i in ids)


def test_tokenizer_specials_and_padding():
    ids = TOKENIZER.encode("hi", bos=True, eos=True)
    assert ids[0] == TOKENIZER.bos_id and ids[-1] == TOKENIZER.eos_id
    batch = TOKENIZER.pad_batch([[1, 2], [3]], 4)
    assert batch.shape == (2, 4)
    assert batch[1, 1] == TOKENIZER.pad_id


# ---------------------------------------------------------------------------
# Synthetic MixInstruct
# ---------------------------------------------------------------------------


def test_dataset_deterministic_and_diverse():
    a = generate_dataset(100, seed=0)
    b = generate_dataset(100, seed=0)
    assert [r.query for r in a] == [r.query for r in b]
    assert len({r.domain for r in a}) == len(DOMAIN_NAMES)


def test_no_member_dominates():
    """The paper's premise: every member is best-in-pool on some domain."""
    comp = np.array([m.competence for m in DEFAULT_POOL])
    best = comp.argmax(axis=0)
    assert len(set(best.tolist())) >= 5
    for j in range(len(DEFAULT_POOL)):
        assert (comp[j] < comp.max(axis=0)).any(), "a member dominates everywhere"


def test_member_response_tracks_competence():
    rng = np.random.default_rng(0)
    recs = generate_dataset(300, seed=1)
    strong = DEFAULT_POOL[1]  # vicuna: high competence on add (idx 4)
    weak = DEFAULT_POOL[3]  # stablelm: low on add
    add_recs = [r for r in recs if r.domain == "add"]
    acc = {m.name: np.mean([member_response(m, r, rng) == r.reference for r in add_recs])
           for m in (strong, weak)}
    assert acc[strong.name] > acc[weak.name] + 0.2


def test_batch_builders_shapes():
    recs = generate_dataset(64, seed=0)
    b = next(iter(lm_batches(recs, 8, 48)))
    assert b["tokens"].shape == (8, 48) and b["loss_mask"].shape == (8, 48)
    assert b["loss_mask"].max() == 1.0
    sb = next(iter(scorer_batches(recs, DEFAULT_POOL, 4, 64, 24)))
    assert sb["enc_tokens"].shape == (4, 64) and sb["dec_tokens"].shape == (4, 24)
    pb = next(iter(predictor_batches(recs, np.zeros((64, 8), np.float32), 4, 32)))
    assert pb["tokens"].shape == (4, 32) and pb["tokens"][0, 0] == TOKENIZER.cls_id


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------


def _quad_params():
    return {"w": jnp.asarray([1.0, -2.0, 3.0]), "b": jnp.asarray(0.5)}


@pytest.mark.parametrize("opt", [AdamW(learning_rate=0.05), Adafactor(learning_rate=0.5)])
def test_optimizers_minimize_quadratic(opt):
    params = _quad_params()
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"])) + jnp.square(p["b"])

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_factored_state_is_small():
    p = {"w": jnp.zeros((64, 128))}
    st_ = Adafactor().init(p)
    n = sum(x.size for x in jax.tree.leaves(st_.slots))
    assert n == 64 + 128  # vr + vc, not 64*128


def test_grad_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped = clip_by_global_norm(g, 1.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule():
    sched = cosine_with_warmup(1.0, warmup=10, total=100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)


# ---------------------------------------------------------------------------
# Trainer + checkpoint
# ---------------------------------------------------------------------------


def test_train_loop_reduces_loss_and_checkpoints(tmp_path):
    cfg = configs.get("smollm-360m").reduced(dtype="float32", num_layers=2, d_model=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    recs = generate_dataset(256, seed=0)
    res = train(
        lambda p, b: model.loss(p, b), params,
        repeat_batches(lambda ep: lm_batches(recs, 8, 48, seed=ep)),
        steps=40, optimizer=AdamW(learning_rate=2e-3), log_every=20, log_fn=lambda s: None,
    )
    assert res.history[-1]["loss"] < res.history[0]["loss"]

    path = os.path.join(tmp_path, "ckpt.npz")
    checkpoint.save(path, res.params)
    restored = checkpoint.restore(path, params)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(res.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
