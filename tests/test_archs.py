"""Per-architecture smoke tests: reduced variant of each assigned arch runs
one forward + one train step on CPU, asserting shapes and no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import build_model

B, S = 2, 16


def _inputs(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend_tokens and not cfg.is_encoder_decoder:
        batch["frontend"] = jax.random.normal(
            jax.random.key(7), (B, cfg.frontend_tokens, cfg.frontend_dim or cfg.d_model)
        )
    if cfg.is_encoder_decoder:
        batch = {"dec_tokens": toks}
        if cfg.frontend_tokens:
            batch["enc_frontend"] = jax.random.normal(
                jax.random.key(7), (B, cfg.enc_seq, cfg.frontend_dim or cfg.d_model)
            )
        else:
            batch["enc_tokens"] = jax.random.randint(jax.random.key(8), (B, 20), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get(arch).reduced(dtype="float32")
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _inputs(cfg, jax.random.key(1))

    if cfg.is_encoder_decoder:
        logits = model.forward(
            params, batch["dec_tokens"],
            enc_frontend=batch.get("enc_frontend"), enc_tokens=batch.get("enc_tokens"),
        )
        exp_s = S
    else:
        logits, _, aux, _ = model.forward(params, batch["tokens"], frontend=batch.get("frontend"))
        exp_s = S + cfg.frontend_tokens
        assert jnp.isfinite(aux)
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    # one SGD step on the model loss — grads finite, loss finite
    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    gnorm = jax.tree.reduce(
        lambda acc, g: acc + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0
    )
    assert jnp.isfinite(gnorm)
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
    loss2 = loss_fn(new_params)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", [a for a in configs.ASSIGNED_ARCHS])
def test_smoke_decode_matches_forward(arch):
    """prefill + single decode step reproduces the full-forward last logits."""
    cfg = configs.get(arch).reduced(dtype="float32")
    if cfg.num_experts:
        # disable capacity dropping so prefill/decode routing agrees exactly
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _inputs(cfg, jax.random.key(1))
    pos = jnp.full((B,), S - 1, jnp.int32)

    if cfg.is_encoder_decoder:
        toks = batch["dec_tokens"]
        full = model.forward(params, toks, enc_frontend=batch.get("enc_frontend"),
                             enc_tokens=batch.get("enc_tokens"))
        cache = model.init_cache(B, 2 * S)
        _, cache = model.prefill(params, toks[:, : S - 1], cache,
                                 enc_frontend=batch.get("enc_frontend"),
                                 enc_tokens=batch.get("enc_tokens"))
        dec, _ = model.decode_step(params, toks[:, S - 1 :], pos, cache)
        last = full[:, -1:]
    else:
        toks = batch["tokens"]
        fe = batch.get("frontend")
        full, _, _, _ = model.forward(params, toks, frontend=fe)
        cache = model.init_cache(B, 2 * S + cfg.frontend_tokens)
        _, cache = model.prefill(params, toks[:, : S - 1], cache, frontend=fe)
        if cfg.frontend_tokens:
            pos = pos + cfg.frontend_tokens
        dec, _ = model.decode_step(params, toks[:, S - 1 :], pos, cache)
        last = full[:, -1:]
    assert jnp.max(jnp.abs(dec - last)) < 5e-4


def test_param_accounting_matches_actual():
    """config.total_params() agrees with the real initialized tree (dense)."""
    for arch in ["smollm-360m", "mamba2-370m"]:
        cfg = configs.get(arch).reduced(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        predicted = cfg.total_params()
        # norms/dt biases are excluded from the analytic count; tolerance 2%
        assert abs(actual - predicted) / actual < 0.02, (arch, actual, predicted)


def test_long_context_support_flags():
    assert configs.get("mamba2-370m").supports_long_context
    assert configs.get("zamba2-2.7b").supports_long_context
    assert not configs.get("whisper-base").supports_long_context
    dense = configs.get("smollm-360m")
    assert not dense.supports_long_context
    assert dataclasses.replace(dense, sliding_window=8192).supports_long_context
