"""Assigned input shapes and per-(arch, shape) execution plans."""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.config import ModelConfig

LONG_CONTEXT_WINDOW = 8192  # sliding-window size for dense archs at 500k


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# train_4k grad-accumulation microbatch counts (activation-memory driven)
MICROBATCHES = {
    "deepseek-v3-671b": 16,
    "arctic-480b": 16,
    "command-r-plus-104b": 16,
    "qwen2.5-32b": 8,
    "minicpm3-4b": 4,
    "zamba2-2.7b": 4,
    "internvl2-1b": 4,
    "mamba2-370m": 4,
    "smollm-360m": 4,
    "whisper-base": 4,
}

# Giant-MoE training states use factored second moments (Adafactor) — full
# Adam fp32 state does not fit 16 GB/chip at these sizes (DESIGN.md).
ADAFACTOR_ARCHS = {"deepseek-v3-671b", "arctic-480b"}


def shape_skip_reason(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """Spec'd skips (recorded in DESIGN.md / EXPERIMENTS.md)."""
    if shape.name == "long_500k":
        if cfg.name.startswith("whisper"):
            return ("enc-dec audio decoder (448-token family spec); 500k decode "
                    "is out-of-family full attention — skipped per spec")
    return None


def adapt_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-specific architecture adaptation.

    ``long_500k`` requires sub-quadratic decode: SSM/hybrid run natively;
    dense/MoE/VLM archs serve with a sliding-window KV cache (window
    LONG_CONTEXT_WINDOW) — the beyond-paper serving feature that makes the
    shape feasible (DESIGN.md §4)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm",):
        if cfg.family == "hybrid":
            # shared attention block also windows its cache at 500k
            return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
        if not cfg.sliding_window:
            return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def microbatches_for(arch: str, mesh_data_shards: int, global_batch: int) -> int:
    m = MICROBATCHES.get(arch, 4)
    while global_batch // m < mesh_data_shards and m > 1:
        m //= 2
    return max(m, 1)
