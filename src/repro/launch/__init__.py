from repro.launch.mesh import make_production_mesh, production_rules
from repro.launch.shapes import INPUT_SHAPES, adapt_config, shape_skip_reason

__all__ = [
    "make_production_mesh",
    "production_rules",
    "INPUT_SHAPES",
    "adapt_config",
    "shape_skip_reason",
]
