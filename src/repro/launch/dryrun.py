"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production mesh, prove memory fits, and extract roofline terms.

MUST be run as a module entry point; the device-count override below has to
execute before jax initializes.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch.mesh import (  # noqa: E402
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    make_production_mesh,
    production_rules,
)
from repro.launch.shapes import INPUT_SHAPES, adapt_config, shape_skip_reason  # noqa: E402
from repro.launch.steps import build_plan  # noqa: E402
from repro.sharding.api import axis_rules  # noqa: E402

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def parse_collective_bytes(hlo_text: str):
    """Per-device bytes moved by each collective kind (result-shape sums of
    the SPMD-partitioned module)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        rhs = stripped.split("=", 1)[1]
        kind = None
        for k in _COLLECTIVES:
            tok = f" {k}("
            if tok in rhs:
                kind = k
                result_part = rhs.split(tok)[0]
                break
        if kind is None:
            continue
        if kind + "-start" in rhs or kind + "-done" in rhs:
            continue
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(result_part):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] += nbytes
        counts[kind] += 1
    return out, counts


def model_flops(cfg, shape) -> float:
    """6·N_active·D (train) or 2·N_active·D (inference forward)."""
    n_active = cfg.active_non_embedding_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/row


def serve_rules_overrides(cfg, mesh) -> dict:
    """Serving weight layout: replicate the FSDP dim of the *non-expert*
    weights when they fit per model shard (kills the per-layer / per-step
    weight all-gathers that dominate decode — §Perf C); the expert bank
    keeps its own ``expert_fsdp`` sharding (it never fits replicated)."""
    model_shards = mesh.shape["model"]
    expert_params = 0
    if cfg.num_experts:
        moe_layers = cfg.num_layers - cfg.first_dense_layers
        expert_params = moe_layers * cfg.num_experts * cfg.mlp_params(cfg.expert_d_ff)
    non_expert = cfg.total_params() - expert_params
    if non_expert * 2 / model_shards < 8e9:
        return {"fsdp": None}
    return {}


def run_one(arch: str, shape_name: str, mesh_name: str, out_dir: str) -> dict:
    shape = INPUT_SHAPES[shape_name]
    base_cfg = configs.get(arch)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    skip = shape_skip_reason(base_cfg, shape)
    if skip:
        rec["skipped"] = skip
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_dev = int(np.prod(list(mesh.shape.values())))
    cfg = adapt_config(base_cfg, shape)
    if shape.kind == "train":
        # sequence-parallel residual stream (Megatron SP): saved activations
        # rest seq-sharded over the model axis (EXPERIMENTS.md §Perf)
        overrides = {"act_seq": "model"}
    else:
        overrides = serve_rules_overrides(cfg, mesh)
    rules = production_rules(mesh, overrides)

    t0 = time.time()
    with axis_rules(rules):
        plan = build_plan(arch, base_cfg, shape, rules)
        lowered = jax.jit(plan.step_fn).lower(*plan.args_sds)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    rec.update(description=plan.description, lower_s=round(t_lower, 2),
               compile_s=round(t_compile, 2), devices=n_dev)

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        }
    except Exception as e:  # pragma: no cover
        rec["memory_error"] = str(e)

    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if k in ("flops", "bytes accessed", "transcendentals")}
    except Exception as e:  # pragma: no cover
        rec["cost_error"] = str(e)

    hlo = compiled.as_text()
    coll_bytes, coll_counts = parse_collective_bytes(hlo)
    rec["collectives"] = {"bytes": coll_bytes, "counts": coll_counts}

    # --- roofline terms (per-device module; see EXPERIMENTS.md §Roofline) ---
    flops_dev = rec.get("cost", {}).get("flops", 0.0)
    bytes_dev = rec.get("cost", {}).get("bytes accessed", 0.0)
    coll_total = float(sum(coll_bytes.values()))
    mf = model_flops(cfg, shape)
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_total / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    rec["roofline"] = {
        **terms,
        "dominant": dominant,
        "model_flops_global": mf,
        "hlo_flops_global": flops_dev * n_dev,
        "useful_flops_ratio": (mf / (flops_dev * n_dev)) if flops_dev else None,
    }

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_name}.json"), "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true", help="every (arch x shape) on --mesh")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        archs = configs.ASSIGNED_ARCHS
        shapes = list(INPUT_SHAPES)
    else:
        archs = [args.arch] if args.arch else configs.ASSIGNED_ARCHS
        shapes = [args.shape] if args.shape else list(INPUT_SHAPES)

    failures = 0
    for arch in archs:
        for shape in shapes:
            tag = f"{arch} x {shape} x {args.mesh}"
            out_path = os.path.join(args.out_dir, f"{arch}_{shape}_{args.mesh}.json")
            if os.path.exists(out_path):
                print(f"[skip-cached] {tag}")
                continue
            try:
                rec = run_one(arch, shape, args.mesh, args.out_dir)
            except Exception:
                failures += 1
                print(f"[FAIL] {tag}")
                traceback.print_exc()
                continue
            if "skipped" in rec:
                print(f"[skipped] {tag}: {rec['skipped']}")
                if args.out_dir:
                    os.makedirs(args.out_dir, exist_ok=True)
                    with open(out_path, "w") as f:
                        json.dump(rec, f, indent=2)
                continue
            r = rec["roofline"]
            mem = rec.get("memory", {})
            print(
                f"[ok] {tag}: compile={rec['compile_s']}s "
                f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                f"collective={r['collective_s']:.4f}s dominant={r['dominant']} "
                f"args={mem.get('argument_size_in_bytes', 0)/1e9:.2f}GB "
                f"temp={mem.get('temp_size_in_bytes', 0)/1e9:.2f}GB"
            )
    if failures:
        raise SystemExit(f"{failures} dry-run failures")


if __name__ == "__main__":
    main()
