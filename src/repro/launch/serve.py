"""Serving driver: stand up the full MODI stack (predictor + knapsack +
pool + GEN-FUSER) and serve a batch of MixInstruct-style queries.

    PYTHONPATH=src python -m repro.launch.serve --budget 0.2 --n 16 [--train-steps 300]

With --train-steps > 0 the paper components (predictor, fuser, scorer) are
trained in-process first; otherwise they run from random init (pipeline
demo only).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.core import (
    EpsilonConstraint,
    ModiPolicy,
    bartscore,
    build_predictor,
)
from repro.data import (
    DEFAULT_POOL,
    TOKENIZER,
    fuser_batches,
    generate_dataset,
    predictor_batches,
    pool_responses,
    query_cost_matrix,
    scorer_batches,
)
from repro.models import build_model
from repro.optim import AdamW
from repro.serve import EnsembleServer
from repro.train import repeat_batches, train
import jax.numpy as jnp


def quality_labels(scorer, scorer_params, recs, responses):
    """BARTScore label matrix [Q, N] under the in-framework scorer."""
    n = len(responses[0])
    out = np.zeros((len(recs), n), np.float32)
    refs = TOKENIZER.pad_batch(
        [TOKENIZER.encode(r.reference, bos=True, eos=True) for r in recs], 32
    )
    mask = (refs != TOKENIZER.pad_id).astype(np.float32)
    for j in range(n):
        # BARTScore conditions on the candidate only (see data.batching)
        cands = TOKENIZER.pad_batch(
            [TOKENIZER.encode(resp[j]) for resp in responses], 64
        )
        out[:, j] = np.asarray(
            bartscore(scorer, scorer_params, jnp.asarray(cands), jnp.asarray(refs), jnp.asarray(mask))
        )
    return out


def build_stack(train_steps: int, seed: int = 0, log=print):
    """Train (or randomly init) scorer, fuser, predictor; return the parts."""
    recs = generate_dataset(3000, seed=seed)
    scorer = build_model(configs.get("bartscore-scorer"))
    scorer_p = scorer.init(jax.random.key(1))
    fuser = build_model(configs.get("gen-fuser"))
    fuser_p = fuser.init(jax.random.key(2))
    predictor = build_predictor(num_models=len(DEFAULT_POOL))
    pred_p = predictor.init(jax.random.key(3))

    if train_steps > 0:
        log(f"[1/4] training BARTScore scorer ({train_steps} steps)")
        scorer_p = train(
            lambda p, b: scorer.loss(p, b), scorer_p,
            repeat_batches(lambda ep: scorer_batches(recs, DEFAULT_POOL, 16, 96, 32, seed=ep)),
            train_steps, optimizer=AdamW(learning_rate=1e-3), log_fn=log,
        ).params
        log(f"[2/4] training GEN-FUSER ({train_steps} steps)")
        fuser_p = train(
            lambda p, b: fuser.loss(p, b), fuser_p,
            repeat_batches(lambda ep: fuser_batches(recs, DEFAULT_POOL, 16, 256, 32, seed=ep)),
            train_steps, optimizer=AdamW(learning_rate=1e-3), log_fn=log,
        ).params
        log("[3/4] labelling member responses with BARTScore")
        lab_recs = recs[:1000]
        responses = pool_responses(DEFAULT_POOL, lab_recs, seed=seed)
        labels = quality_labels(scorer, scorer_p, lab_recs, responses)
        log(f"      label matrix {labels.shape}, per-member mean: "
            + np.array2string(labels.mean(0), precision=2))
        log(f"[4/4] training MODI predictor ({train_steps} steps, Huber d=0.3, Adam 3e-4)")
        pred_p = train(
            lambda p, b, r: predictor.loss(p, b, r), pred_p,
            repeat_batches(lambda ep: predictor_batches(lab_recs, labels, 16, 64, seed=ep)),
            train_steps, optimizer=AdamW(learning_rate=3e-4, b1=0.9, b2=0.98, weight_decay=0.01),
            rng=jax.random.key(7), log_fn=log,
        ).params
    return recs, scorer, scorer_p, fuser, fuser_p, predictor, pred_p


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=float, default=0.2, help="epsilon as fraction of full-ensemble cost")
    ap.add_argument("--n", type=int, default=8, help="queries to serve")
    ap.add_argument("--train-steps", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    recs, scorer, scorer_p, fuser, fuser_p, predictor, pred_p = build_stack(
        args.train_steps, args.seed
    )
    server = EnsembleServer(
        DEFAULT_POOL,
        ModiPolicy(EpsilonConstraint(args.budget)),
        predictor, pred_p, fuser, fuser_p,
    )
    batch = generate_dataset(args.n, seed=args.seed + 999)
    result = server.serve(batch)
    for rec, resp, frac, row in zip(batch, result.responses, result.cost_fraction, result.mask):
        members = [DEFAULT_POOL[j].name for j in range(len(row)) if row[j]]
        print(f"\nQ: {rec.query}\n   ref: {rec.reference}\n   MODI({frac:.0%} cost, {members}): {resp!r}")
    print("\nstats:", server.stats,
          f"\nmean cost fraction: {result.cost_fraction.mean():.3f} (budget {args.budget})")


if __name__ == "__main__":
    main()
