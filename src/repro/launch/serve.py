"""Serving driver: stand up the full MODI stack (predictor + knapsack +
pool + GEN-FUSER) and serve MixInstruct-style queries.

    PYTHONPATH=src python -m repro.launch.serve --budget 0.2 --n 16 \
        [--policy modi] [--train-steps 300] [--online]

``build_stack`` trains (or randomly inits, for a pipeline demo) the
scorer/fuser/predictor; ``main`` composes the layered serving stack:
the policy is constructed by registry name (``repro.core.make_policy``),
the ``EnsembleServer`` pairs it with a member backend, and ``--online``
routes the queries one at a time through the admission
``repro.serve.Scheduler`` instead of one offline batch — both paths
produce identical responses.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.core import bartscore, build_predictor, make_policy
from repro.data import (
    DEFAULT_POOL,
    TOKENIZER,
    fuser_batches,
    generate_dataset,
    predictor_batches,
    pool_responses,
    query_cost_matrix,
    scorer_batches,
)
from repro.models import build_model
from repro.optim import AdamW
from repro.launch.mesh import cluster_host_devices
from repro.serve import (
    AdmissionControl,
    ClusterRouter,
    EnsembleServer,
    HealthMonitor,
    PlacementPlan,
    RequestShed,
    Scheduler,
    requests_from_records,
)
from repro.train import repeat_batches, train
import jax.numpy as jnp


def quality_labels(scorer, scorer_params, recs, responses):
    """BARTScore label matrix [Q, N] under the in-framework scorer."""
    n = len(responses[0])
    out = np.zeros((len(recs), n), np.float32)
    refs = TOKENIZER.pad_batch(
        [TOKENIZER.encode(r.reference, bos=True, eos=True) for r in recs], 32
    )
    mask = (refs != TOKENIZER.pad_id).astype(np.float32)
    for j in range(n):
        # BARTScore conditions on the candidate only (see data.batching)
        cands = TOKENIZER.pad_batch(
            [TOKENIZER.encode(resp[j]) for resp in responses], 64
        )
        out[:, j] = np.asarray(
            bartscore(scorer, scorer_params, jnp.asarray(cands), jnp.asarray(refs), jnp.asarray(mask))
        )
    return out


def build_stack(train_steps: int, seed: int = 0, log=print):
    """Train (or randomly init) scorer, fuser, predictor; return the parts."""
    recs = generate_dataset(3000, seed=seed)
    scorer = build_model(configs.get("bartscore-scorer"))
    scorer_p = scorer.init(jax.random.key(1))
    fuser = build_model(configs.get("gen-fuser"))
    fuser_p = fuser.init(jax.random.key(2))
    predictor = build_predictor(num_models=len(DEFAULT_POOL))
    pred_p = predictor.init(jax.random.key(3))

    if train_steps > 0:
        log(f"[1/4] training BARTScore scorer ({train_steps} steps)")
        scorer_p = train(
            lambda p, b: scorer.loss(p, b), scorer_p,
            repeat_batches(lambda ep: scorer_batches(recs, DEFAULT_POOL, 16, 96, 32, seed=ep)),
            train_steps, optimizer=AdamW(learning_rate=1e-3), log_fn=log,
        ).params
        log(f"[2/4] training GEN-FUSER ({train_steps} steps)")
        fuser_p = train(
            lambda p, b: fuser.loss(p, b), fuser_p,
            repeat_batches(lambda ep: fuser_batches(recs, DEFAULT_POOL, 16, 256, 32, seed=ep)),
            train_steps, optimizer=AdamW(learning_rate=1e-3), log_fn=log,
        ).params
        log("[3/4] labelling member responses with BARTScore")
        lab_recs = recs[:1000]
        responses = pool_responses(DEFAULT_POOL, lab_recs, seed=seed)
        labels = quality_labels(scorer, scorer_p, lab_recs, responses)
        log(f"      label matrix {labels.shape}, per-member mean: "
            + np.array2string(labels.mean(0), precision=2))
        log(f"[4/4] training MODI predictor ({train_steps} steps, Huber d=0.3, Adam 3e-4)")
        pred_p = train(
            lambda p, b, r: predictor.loss(p, b, r), pred_p,
            repeat_batches(lambda ep: predictor_batches(lab_recs, labels, 16, 64, seed=ep)),
            train_steps, optimizer=AdamW(learning_rate=3e-4, b1=0.9, b2=0.98, weight_decay=0.01),
            rng=jax.random.key(7), log_fn=log,
        ).params
    return recs, scorer, scorer_p, fuser, fuser_p, predictor, pred_p


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=float, default=0.2, help="epsilon as fraction of full-ensemble cost")
    ap.add_argument("--n", type=int, default=8, help="queries to serve")
    ap.add_argument("--policy", type=str, default="modi", help="selection policy registry name")
    ap.add_argument("--train-steps", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--online", action="store_true",
                    help="serve one request at a time through the admission Scheduler")
    ap.add_argument("--max-batch-size", type=int, default=4, help="scheduler micro-batch size")
    ap.add_argument("--max-wait-ticks", type=int, default=4,
                    help="dispatch a queued request after this many ticks")
    ap.add_argument("--deadline-ticks", type=int, default=None,
                    help="per-request dispatch deadline (EDF batch formation)")
    ap.add_argument("--priority", type=int, default=0,
                    help="request priority (breaks deadline ties; larger = sooner)")
    ap.add_argument("--admission-window", type=int, default=8,
                    help="rolling fleet-budget window, in scheduler ticks")
    ap.add_argument("--admission-downgrade", type=float, default=None,
                    help="window cost fraction past which new requests are "
                         "downgraded to half the per-query budget")
    ap.add_argument("--admission-shed", type=float, default=None,
                    help="window cost fraction past which new requests are shed")
    ap.add_argument("--admission-deadline", action="store_true",
                    help="shed requests whose predicted queue delay already "
                         "exceeds their deadline")
    ap.add_argument("--hosts", type=int, default=None,
                    help="shard the pool over this many placement hosts "
                         "(cluster serving; logical-only when the device "
                         "fleet cannot be split)")
    ap.add_argument("--placement", type=str, default="auto",
                    choices=("auto", "round-robin"),
                    help="member->host placer: greedy cost/VRAM-balanced "
                         "or round-robin")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replica hosts per member (auto placement only; "
                         "replicated members survive a host failure)")
    ap.add_argument("--fanout", action="store_true",
                    help="serve a batch's per-host member shards "
                         "concurrently on per-host executors (outputs are "
                         "byte-identical to sequential routing)")
    ap.add_argument("--probation-ticks", type=int, default=0,
                    help="ticks a recovered host waits past its recovery "
                         "tick before being re-admitted to routing")
    ap.add_argument("--recover", type=str, default=None, metavar="HOST:TICK",
                    help="schedule a dead host's recovery (comma-separated "
                         "host:tick pairs; re-admitted after probation)")
    ap.add_argument("--rebalance", action="store_true",
                    help="re-place members that lost replica redundancy "
                         "onto surviving hosts at the next maintenance tick")
    ap.add_argument("--probe-interval", type=int, default=None,
                    help="run health probes every this many scheduler "
                         "ticks (probe-driven death/revival replaces the "
                         "--recover schedule, which then describes when "
                         "each host's underlying health returns)")
    ap.add_argument("--probe-failures", type=int, default=2,
                    help="consecutive probe failures that open a host's "
                         "circuit breaker (mark it dead)")
    ap.add_argument("--shard-deadline", type=float, default=None,
                    metavar="SECONDS",
                    help="wall-clock deadline per fan-out shard; a late "
                         "shard is cancelled and hedged onto replica hosts")
    ap.add_argument("--hedge", action="store_true",
                    help="re-route grey-slow dispatches to an alive "
                         "replica at consume time (straggler hedging)")
    ap.add_argument("--allow-degraded", action="store_true",
                    help="serve partial-ensemble responses (knapsack over "
                         "the survivors, tagged degraded) when members "
                         "are unavailable, instead of failing the batch")
    ap.add_argument("--async", dest="async_dispatch", action="store_true",
                    help="serve batches on a dispatch worker thread so "
                         "submit never blocks on a batch (--online only)")
    ap.add_argument("--stream", action="store_true",
                    help="token-level continuous batching: fuse through "
                         "the persistent in-flight decode state and print "
                         "tokens as they stream (--online only; final "
                         "responses are byte-identical)")
    ap.add_argument("--stream-capacity", type=int, default=8,
                    help="decode slots in the persistent in-flight batch")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="max rows per prefill call on the streaming path "
                         "(bounds how long a prompt burst can stall joins)")
    args = ap.parse_args()

    recs, scorer, scorer_p, fuser, fuser_p, predictor, pred_p = build_stack(
        args.train_steps, args.seed
    )
    server = EnsembleServer(
        DEFAULT_POOL,
        make_policy(args.policy, budget=args.budget),
        predictor, pred_p, fuser, fuser_p,
    )
    if args.hosts:
        groups = cluster_host_devices(args.hosts)
        devices = [d for g in groups for d in g] or None
        if args.placement == "round-robin":
            plan = PlacementPlan.round_robin(len(DEFAULT_POOL), args.hosts,
                                             devices=devices)
        else:
            plan = PlacementPlan.auto(DEFAULT_POOL, args.hosts,
                                      replicas=args.replicas, devices=devices)
        recovery = {}
        if args.recover:
            for pair in args.recover.split(","):
                host, _, tick = pair.partition(":")
                recovery.setdefault(int(host), []).append(int(tick))
        recovery = {h: tuple(sorted(t)) for h, t in recovery.items()}
        health = None
        if args.probe_interval is not None:
            # probe-driven health: the recovery schedule feeds the
            # monitor's half-open probes instead of the router's
            # schedule-driven revival
            health = HealthMonitor(plan,
                                   probe_interval=args.probe_interval,
                                   probe_failures=args.probe_failures,
                                   recovery=recovery)
            recovery = {}
        server.backend = ClusterRouter(
            server.backend, plan=plan, fanout=args.fanout,
            host_recovery=recovery,
            probation_ticks=args.probation_ticks, rebalance=args.rebalance,
            health=health, hedge_stragglers=args.hedge,
            shard_deadline_s=args.shard_deadline)
        print(f"cluster placement ({args.placement}, {args.hosts} hosts"
              + (", fanout" if args.fanout else "") + "):")
        print(plan.describe())
    if args.online:
        # pre-compile every bucket a scheduler batch can map to: early
        # micro-batches dispatch before the queue fills, so sizes
        # 1..max_batch_size all occur, and max_batch_size itself may round
        # up to a rung above it
        rungs = sorted({server.bucket_ladder.batch_bucket(b)
                        for b in range(1, args.max_batch_size + 1)})
        server.warm([(b, server.max_new_tokens) for b in rungs])
    batch = generate_dataset(args.n, seed=args.seed + 999)
    if args.online:
        admission = None
        if (args.admission_downgrade is not None
                or args.admission_shed is not None or args.admission_deadline):
            admission = AdmissionControl(
                window_ticks=args.admission_window,
                downgrade_fraction=args.admission_downgrade,
                downgrade_budget=args.budget / 2,
                shed_fraction=args.admission_shed,
                deadline_aware=args.admission_deadline,
            )
        scheduler = Scheduler(server, max_batch_size=args.max_batch_size,
                              max_wait_ticks=args.max_wait_ticks,
                              admission=admission,
                              sync=not args.async_dispatch,
                              allow_degraded=args.allow_degraded,
                              stream=args.stream,
                              stream_capacity=args.stream_capacity,
                              prefill_chunk=args.prefill_chunk)
        futures = [
            scheduler.submit(req)
            for req in requests_from_records(
                batch, priority=args.priority,
                deadline_ticks=args.deadline_ticks)
        ]
        scheduler.flush()
        scheduler.join()
        out = []
        for f in futures:
            try:
                if args.stream:
                    resp = None
                    for ev in f.stream():
                        if ev.final:
                            resp = ev.response
                        else:
                            print(f"  [req {ev.seq} +{len(ev.tokens)} tok] "
                                  f"{ev.text!r}")
                    out.append(resp)
                else:
                    out.append(f.result())
            except RequestShed:
                out.append(None)
        scheduler.close()
        shed = sum(r is None for r in out)
        kept = [(r, rec) for r, rec in zip(out, batch) if r is not None]
        out = [r for r, _ in kept]
        batch = [rec for _, rec in kept]
        responses = [r.text for r in out]
        fractions = [r.cost_fraction for r in out]
        masks = [r.mask for r in out]
        print(f"scheduler: {scheduler.stats}"
              + (f"  ({shed} requests shed by admission control)" if shed else ""))
    else:
        result = server.serve(batch)
        responses, fractions, masks = result.responses, result.cost_fraction, result.mask
    for rec, resp, frac, row in zip(batch, responses, fractions, masks):
        members = [DEFAULT_POOL[j].name for j in range(len(row)) if row[j]]
        print(f"\nQ: {rec.query}\n   ref: {rec.reference}\n   "
              f"{args.policy}({frac:.0%} cost, {members}): {resp!r}")
    mean_frac = (f"{np.mean(fractions):.3f}" if fractions is not None and len(fractions)
                 else "n/a (all requests shed)")
    print("\nstats:", server.stats,
          f"\nmean cost fraction: {mean_frac} (budget {args.budget})")


if __name__ == "__main__":
    main()
