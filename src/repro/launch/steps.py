"""Step functions + sharded input specs for every (arch × input shape).

Everything here is ``jax.eval_shape``-driven: no real allocation happens
until a caller runs the compiled step.  The dry-run lowers these with
ShapeDtypeStructs whose ``.sharding`` carries the full GSPMD layout:

* params — FSDP(ZeRO-3)+tensor-parallel specs from sharding.params;
* optimizer state — Adam moments like params; Adafactor factored slots with
  the corresponding reduced specs;
* train batches — batch dim over ("pod","data");
* KV/state caches — batch over data, cache length over model
  (flash-decoding layout).

``train_step`` is grad-accumulation microbatched (activation memory) with
remat-per-layer inside the layer scan.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.shapes import (
    ADAFACTOR_ARCHS,
    InputShape,
    adapt_config,
    microbatches_for,
    shape_skip_reason,
)
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.transformer import DecoderLM
from repro.optim import AdamW
from repro.optim.adafactor import Adafactor, FactoredSlot
from repro.sharding.api import AxisRules
from repro.sharding.params import infer_param_specs, spec_drop_dim


# ---------------------------------------------------------------------------
# Spec plumbing
# ---------------------------------------------------------------------------


def _data_axes(rules: AxisRules) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in rules.mesh.axis_names)


def _axes_spec(axes: Tuple[str, ...]):
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def _div_axes(dim: int, axes: Tuple[str, ...], rules: AxisRules) -> Tuple[str, ...]:
    while axes:
        prod = int(np.prod([rules.mesh.shape[a] for a in axes]))
        if dim % prod == 0:
            return axes
        axes = axes[:-1]
    return ()


def _sds(shape, dtype, rules: AxisRules, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(rules.mesh, spec))


def _tree_sds(shapes: Any, specs: Any, rules: AxisRules) -> Any:
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, rules, sp), shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def batch_sds(shape, dtype, rules: AxisRules):
    """Batch-dim-sharded array spec (dim 0 over pod+data, div-checked)."""
    axes = _div_axes(shape[0], _data_axes(rules), rules)
    spec = P(_axes_spec(axes), *([None] * (len(shape) - 1)))
    return _sds(shape, dtype, rules, spec)


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------

_CACHE_RULES = {
    # name -> logical layout for the unstacked (per-layer) rank
    "k": ("batch", "cache_seq", None, None),
    "v": ("batch", "cache_seq", None, None),
    "ckv": ("batch", "cache_seq", None),
    "kr": ("batch", "cache_seq", None),
    "pos": ("batch", "cache_seq"),
    "h": ("batch", "heads", None, None),
    "conv": ("batch", None, "mlp"),
    "ck": ("batch", None, "heads", None),
    "cv": ("batch", None, "heads", None),
}


def infer_cache_specs(cache_shapes: Any, rules: AxisRules) -> Any:
    def leaf_spec(path, leaf):
        name = None
        for k in reversed(path):
            if hasattr(k, "key"):
                name = str(k.key)
                break
        logical = _CACHE_RULES.get(name)
        rank = len(leaf.shape)
        if logical is None:
            return P()
        if rank == len(logical) + 1:  # stacked over layers
            logical = (None,) + logical
        parts = []
        used: set = set()
        for dim, lg in zip(leaf.shape, logical):
            if lg is None:
                parts.append(None)
                continue
            mesh_axes = rules.rules.get(lg)
            if mesh_axes is None:
                parts.append(None)
                continue
            if isinstance(mesh_axes, str):
                mesh_axes = (mesh_axes,)
            mesh_axes = tuple(a for a in mesh_axes if a not in used)
            mesh_axes = _div_axes(dim, mesh_axes, rules)
            if not mesh_axes:
                parts.append(None)
                continue
            used.update(mesh_axes)
            parts.append(_axes_spec(mesh_axes))
        return P(*parts)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shapes)


# ---------------------------------------------------------------------------
# Optimizer state specs
# ---------------------------------------------------------------------------


def make_optimizer(arch: str):
    if arch in ADAFACTOR_ARCHS:
        return Adafactor(learning_rate=1e-3)
    return AdamW(learning_rate=3e-4)


def opt_state_specs(opt, param_specs: Any, param_shapes: Any) -> Any:
    if isinstance(opt, Adafactor):
        def slot_spec(spec, shape_struct):
            rank = len(shape_struct.shape)
            if rank >= 2:
                return FactoredSlot(
                    vr=spec_drop_dim(spec, rank, -1), vc=spec_drop_dim(spec, rank, -2)
                )
            return spec

        slots = jax.tree.map(
            slot_spec, param_specs, param_shapes,
            is_leaf=lambda x: isinstance(x, P),
        )
        from repro.optim.adafactor import AdafactorState

        return AdafactorState(step=P(), slots=slots)
    from repro.optim.adamw import OptState

    return OptState(step=P(), mu=param_specs, nu=param_specs)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepPlan:
    """Everything the dry-run needs: the step callable + its arg specs."""

    arch: str
    shape: InputShape
    cfg: ModelConfig
    step_fn: Callable
    args_sds: Tuple
    description: str


def _constrain_batch(x, rules: AxisRules):
    axes = _div_axes(x.shape[1], _data_axes(rules), rules)  # dim 1 after micro split
    spec = P(None, _axes_spec(axes), *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def make_train_step(cfg: ModelConfig, arch: str, rules: AxisRules, num_micro: int):
    model = build_model(cfg)
    opt = make_optimizer(arch)
    p_shapes = jax.eval_shape(model.init, jax.random.key(0))
    p_specs = infer_param_specs(p_shapes, rules)
    grad_shardings = jax.tree.map(
        lambda sp: NamedSharding(rules.mesh, sp), p_specs,
        is_leaf=lambda x: isinstance(x, P),
    )

    def constrain_grads(grads):
        # keep gradients in the params' FSDP+TP layout — XLA otherwise
        # chooses replicated for gather-adjoint grads (embed, low-rank projs)
        return jax.tree.map(jax.lax.with_sharding_constraint, grads, grad_shardings)

    def micro_loss(params, mbatch):
        loss, _ = model.loss(params, mbatch, remat=True)
        return loss

    def train_step(params, opt_state, batch):
        gb = jax.tree.leaves(batch)[0].shape[0]
        if num_micro == 1:
            loss, grads = jax.value_and_grad(micro_loss)(params, batch)
            grads = constrain_grads(grads)
        else:
            micro = jax.tree.map(
                lambda x: _constrain_batch(
                    x.reshape(num_micro, gb // num_micro, *x.shape[1:]), rules
                ),
                batch,
            )
            zero_g = constrain_grads(jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params))

            def body(carry, mbatch):
                gsum, lsum = carry
                l, g = jax.value_and_grad(micro_loss)(params, mbatch)
                g = constrain_grads(g)
                gsum = jax.tree.map(lambda a, b: a + b, gsum, g)
                return (gsum, lsum + l), None

            (grads, loss), _ = jax.lax.scan(body, (zero_g, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / num_micro, grads)
            loss = loss / num_micro
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, loss

    return model, opt, train_step


def make_prefill_step(model):
    if isinstance(model, EncDecLM):
        def prefill_step(params, batch, cache):
            return model.prefill(
                params, batch["dec_tokens"], cache, enc_frontend=batch.get("enc_frontend")
            )
    else:
        def prefill_step(params, batch, cache):
            return model.prefill(params, batch["tokens"], cache, frontend=batch.get("frontend"))
    return prefill_step


def make_decode_step(model):
    def decode_step(params, token, pos, cache):
        return model.decode_step(params, token, pos, cache)

    return decode_step


# ---------------------------------------------------------------------------
# Input construction per (arch × shape)
# ---------------------------------------------------------------------------


def _train_batch_sds(cfg: ModelConfig, shape: InputShape, rules: AxisRules) -> Dict:
    gb, s = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        batch = {
            "dec_tokens": batch_sds((gb, s), jnp.int32, rules),
            "enc_frontend": batch_sds(
                (gb, cfg.enc_seq, cfg.frontend_dim or cfg.d_model), jnp.bfloat16, rules
            ),
            "loss_mask": batch_sds((gb, s), jnp.float32, rules),
        }
        return batch
    text = s - cfg.frontend_tokens
    batch = {
        "tokens": batch_sds((gb, text), jnp.int32, rules),
        "loss_mask": batch_sds((gb, text), jnp.float32, rules),
    }
    if cfg.frontend_tokens:
        batch["frontend"] = batch_sds(
            (gb, cfg.frontend_tokens, cfg.frontend_dim or cfg.d_model), jnp.bfloat16, rules
        )
    return batch


def _params_sds(model, rules: AxisRules):
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    specs = infer_param_specs(shapes, rules)
    return shapes, specs, _tree_sds(shapes, specs, rules)


def _cache_sds(model, batch: int, max_seq: int, rules: AxisRules):
    shapes = jax.eval_shape(lambda: model.init_cache(batch, max_seq))
    specs = infer_cache_specs(shapes, rules)
    return _tree_sds(shapes, specs, rules)


def build_plan(arch: str, cfg: ModelConfig, shape: InputShape, rules: AxisRules) -> StepPlan:
    """Assemble the (step_fn, arg specs) pair the dry-run lowers."""
    cfg = adapt_config(cfg, shape)
    mesh = rules.mesh
    data_shards = int(np.prod([mesh.shape[a] for a in _data_axes(rules)]))

    if shape.kind == "train":
        num_micro = microbatches_for(arch, data_shards, shape.global_batch)
        model, opt, train_step = make_train_step(cfg, arch, rules, num_micro)
        p_shapes, p_specs, p_sds = _params_sds(model, rules)
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_specs = opt_state_specs(opt, p_specs, p_shapes)
        o_sds = _tree_sds(o_shapes, o_specs, rules)
        b_sds = _train_batch_sds(cfg, shape, rules)
        return StepPlan(
            arch, shape, cfg, train_step, (p_sds, o_sds, b_sds),
            f"train_step micro={num_micro} opt={type(opt).__name__}",
        )

    model = build_model(cfg)
    p_shapes, p_specs, p_sds = _params_sds(model, rules)

    if shape.kind == "prefill":
        gb, s = shape.global_batch, shape.seq_len
        cache_sds = _cache_sds(model, gb, s, rules)
        if cfg.is_encoder_decoder:
            batch = {
                "dec_tokens": batch_sds((gb, s), jnp.int32, rules),
                "enc_frontend": batch_sds(
                    (gb, cfg.enc_seq, cfg.frontend_dim or cfg.d_model), jnp.bfloat16, rules
                ),
            }
        else:
            text = s - cfg.frontend_tokens
            batch = {"tokens": batch_sds((gb, text), jnp.int32, rules)}
            if cfg.frontend_tokens:
                batch["frontend"] = batch_sds(
                    (gb, cfg.frontend_tokens, cfg.frontend_dim or cfg.d_model),
                    jnp.bfloat16, rules,
                )
        return StepPlan(
            arch, shape, cfg, make_prefill_step(model), (p_sds, batch, cache_sds),
            "prefill_step (chunked attention)",
        )

    # decode: ONE new token with a seq_len-deep cache
    gb, s = shape.global_batch, shape.seq_len
    cache_sds = _cache_sds(model, gb, s, rules)
    token = batch_sds((gb, 1), jnp.int32, rules)
    pos = batch_sds((gb,), jnp.int32, rules)
    slots = model.cache_slots(s) if hasattr(model, "cache_slots") else s
    return StepPlan(
        arch, shape, cfg, make_decode_step(model), (p_sds, token, pos, cache_sds),
        f"serve_step decode (cache slots={slots})",
    )
