"""CPU-runnable training driver for any assigned architecture (reduced
variant) or the paper's own components at full (laptop) scale.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --steps 50

Reduced variants keep the family topology (MoE routing, SSD scan, MLA,
hybrid shared attention) so the driver exercises the same code paths the
production mesh runs.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.data import DEFAULT_POOL, generate_dataset, lm_batches, scorer_batches
from repro.models import build_model
from repro.optim import AdamW, cosine_with_warmup
from repro.train import checkpoint, repeat_batches, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=configs.ASSIGNED_ARCHS + configs.EXTRA_ARCHS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=96)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None, help="checkpoint path (.npz)")
    ap.add_argument("--full", action="store_true", help="use the full (not reduced) config")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if not args.full and args.arch in configs.ASSIGNED_ARCHS:
        cfg = cfg.reduced(dtype="float32")
    print(f"training {cfg.name}: {cfg.total_params()/1e6:.1f}M params "
          f"({cfg.family}, {cfg.num_layers}L d={cfg.d_model})")

    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    recs = generate_dataset(4000, seed=args.seed)

    if cfg.is_encoder_decoder:
        batches = repeat_batches(
            lambda ep: scorer_batches(recs, DEFAULT_POOL, args.batch, args.seq, 48, seed=ep)
        )
    else:
        def to_batch(ep):
            for b in lm_batches(recs, args.batch, args.seq, seed=ep):
                if cfg.frontend_tokens:
                    b = dict(b)
                    b["frontend"] = np.zeros(
                        (args.batch, cfg.frontend_tokens, cfg.frontend_dim or cfg.d_model),
                        np.float32,
                    )
                yield b
        batches = repeat_batches(to_batch)

    opt = AdamW(learning_rate=cosine_with_warmup(args.lr, 20, args.steps))
    result = train(lambda p, b: model.loss(p, b), params, batches, args.steps, optimizer=opt)
    if args.save:
        checkpoint.save(args.save, result.params)
        print(f"saved -> {args.save}")
    print("final:", result.history[-1])


if __name__ == "__main__":
    main()
