"""Production mesh construction (TPU v5e target).

Importing this module never touches jax device state; both helpers are
functions.  The dry-run forces 512 host devices (see dryrun.py) so both the
single-pod 16x16 and the 2-pod 2x16x16 meshes can be built.
"""

from __future__ import annotations

from typing import List, Mapping, Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.sharding.api import (
    AxisRules,
    default_axis_rules,
    host_mesh,
    partition_devices,
)

# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape} but found {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def production_rules(mesh: Mesh, overrides: Optional[Mapping] = None) -> AxisRules:
    return default_axis_rules(mesh, overrides)


def cluster_host_devices(n_hosts: int) -> List[tuple]:
    """Device groups for the serving cluster's logical hosts.

    Partitions the visible fleet into ``n_hosts`` contiguous groups (one
    per placement host — see ``repro.serve.cluster``).  When the fleet is
    smaller than the host count (the 1-CPU default), returns empty groups:
    the placement layer then runs logical-only, which routes identically —
    run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
    CI cluster job does) to exercise real per-host meshes."""
    devices = jax.devices()
    if len(devices) < n_hosts or len(devices) % n_hosts != 0:
        return [() for _ in range(n_hosts)]
    return [tuple(g) for g in partition_devices(devices, n_hosts)]


def make_host_meshes(n_hosts: int) -> List[Optional[Mesh]]:
    """One (data, model) mesh per cluster host, or Nones when the fleet
    cannot be split evenly (logical-only placement)."""
    return [host_mesh(g) if g else None for g in cluster_host_devices(n_hosts)]
