"""Decoder-only language model: composable segment stack over all families.

A model is a sequence of *segments*, each a run of identical blocks executed
with ``jax.lax.scan`` over stacked parameters (small HLO at any depth).
Heterogeneous stacks (DeepSeek dense prefix + MoE body, Zamba2 mamba runs
with a weight-tied shared attention block) are expressed as multiple
segments.  The Zamba2 shared block's parameters live once at the top level
and are re-applied at every marker — caches are per-invocation.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import blocks, ssm as ssm_mod
from repro.models.config import ModelConfig, validate_config
from repro.models.layers import (
    apply_norm,
    chunked_ce_from_hidden,
    cross_entropy,
    dense_init,
    embed_tokens,
    init_embedding,
    init_norm,
    lm_logits,
)
from repro.sharding import logical_constraint


def model_segments(cfg: ModelConfig) -> List[Tuple[str, int]]:
    """(kind, count) plan for the decoder stack."""
    if cfg.family == "ssm":
        return [("mamba", cfg.num_layers)]
    if cfg.family == "hybrid":
        segs: List[Tuple[str, int]] = []
        remaining = cfg.num_layers
        period = cfg.attn_every or cfg.num_layers
        while remaining > 0:
            run = min(period, remaining)
            segs.append(("mamba", run))
            remaining -= run
            if remaining >= 0 and run == period:
                segs.append(("shared_attn", 1))
        return segs
    if cfg.num_experts:
        segs = []
        if cfg.first_dense_layers:
            segs.append(("dense", cfg.first_dense_layers))
        segs.append(("moe", cfg.num_layers - cfg.first_dense_layers))
        return segs
    return [("dense", cfg.num_layers)]


class DecoderLM:
    """Stateless functional model bound to a config."""

    def __init__(self, cfg: ModelConfig):
        validate_config(cfg)
        self.cfg = cfg
        self.segments = model_segments(cfg)
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------------
    # Params
    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        cfg, dtype = self.cfg, self.dtype
        keys = jax.random.split(key, len(self.segments) + 5)
        params: dict = {"embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dtype)}
        segs = {}
        for si, (kind, count) in enumerate(self.segments):
            if kind == "shared_attn":
                if "shared_attn" not in params:
                    params["shared_attn"] = blocks.init_shared_attn(keys[1], cfg, dtype)
                continue
            layer_keys = jax.random.split(keys[si + 2], count)
            segs[str(si)] = jax.vmap(lambda k: blocks.init_block(k, kind, cfg, dtype))(layer_keys)
        params["segs"] = segs
        params["final_norm"] = init_norm(cfg.d_model, dtype, cfg.norm)
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(keys[-1], cfg.d_model, (cfg.d_model, cfg.vocab_size), dtype)
        if cfg.frontend_tokens:
            fdim = cfg.frontend_dim or cfg.d_model
            params["frontend_proj"] = dense_init(keys[-2], fdim, (fdim, cfg.d_model), dtype)
        if cfg.mtp:
            params["mtp"] = {
                "norm_h": init_norm(cfg.d_model, dtype, cfg.norm),
                "norm_e": init_norm(cfg.d_model, dtype, cfg.norm),
                "proj": dense_init(keys[-3], 2 * cfg.d_model, (2 * cfg.d_model, cfg.d_model), dtype),
                "block": blocks.init_block(keys[-4], "dense", cfg, dtype),
            }
        return params

    # ------------------------------------------------------------------
    # Caches
    # ------------------------------------------------------------------
    def cache_slots(self, max_seq: int) -> int:
        if self.cfg.sliding_window:
            return min(self.cfg.sliding_window, max_seq)
        return max_seq

    def init_cache(self, batch: int, max_seq: int) -> dict:
        cfg, dtype = self.cfg, self.dtype
        slots = self.cache_slots(max_seq)
        caches = {}
        for si, (kind, count) in enumerate(self.segments):
            if kind == "mamba":
                one = ssm_mod.init_ssm_cache(cfg, batch, dtype)
                caches[str(si)] = jax.tree.map(
                    lambda t: jnp.broadcast_to(t[None], (count,) + t.shape), one
                )
            elif kind == "shared_attn":
                caches[str(si)] = attn_mod.init_cache(cfg, batch, slots, dtype)
            else:
                one = attn_mod.init_cache(cfg, batch, slots, dtype)
                caches[str(si)] = jax.tree.map(
                    lambda t: jnp.broadcast_to(t[None], (count,) + t.shape), one
                )
        return caches

    # ------------------------------------------------------------------
    # Embedding / head
    # ------------------------------------------------------------------
    def _embed_inputs(self, params, tokens, frontend):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens)
        if cfg.frontend_tokens:
            if frontend is None:
                raise ValueError(f"{cfg.name} requires frontend embeddings")
            fe = frontend.astype(x.dtype) @ params["frontend_proj"]
            x = jnp.concatenate([fe, x], axis=1)
        return x.astype(self.dtype)

    def _head(self, params, x):
        cfg = self.cfg
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            return lm_logits(params["embed"], x, transpose=True)
        return lm_logits(params["lm_head"], x, transpose=False)

    # ------------------------------------------------------------------
    # Full-sequence forward (training / prefill)
    # ------------------------------------------------------------------
    def forward(
        self,
        params: dict,
        tokens: jax.Array,
        frontend: Optional[jax.Array] = None,
        cache: Optional[dict] = None,
        remat: bool = False,
        positions: Optional[jax.Array] = None,
        skip_head: bool = False,
    ):
        """Returns (logits, new_cache, aux_loss, hidden).

        ``positions``: optional [B, S] absolute positions; -1 marks padding
        (masked out of attention and dropped from the KV cache)."""
        cfg = self.cfg
        x = self._embed_inputs(params, tokens, frontend)
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        x = logical_constraint(x, "batch", "seq", "embed")
        aux_total = jnp.zeros((), jnp.float32)
        new_caches: dict = {}
        for si, (kind, count) in enumerate(self.segments):
            if kind == "shared_attn":
                c = cache[str(si)] if cache is not None else None
                x, nc = blocks.shared_attn_forward(params["shared_attn"], x, positions, cfg, c)
                if cache is not None:
                    new_caches[str(si)] = nc
                continue
            seg_p = params["segs"][str(si)]

            if cache is not None:
                def body(xc, inp, _kind=kind):
                    p_l, c_l = inp
                    y, nc, aux = blocks.block_forward(p_l, _kind, xc, positions, cfg, c_l)
                    return y, (nc, aux)
                fn = jax.checkpoint(body) if remat else body
                x, (ncs, auxs) = jax.lax.scan(fn, x, (seg_p, cache[str(si)]))
                new_caches[str(si)] = ncs
            else:
                def body(xc, p_l, _kind=kind):
                    y, _, aux = blocks.block_forward(p_l, _kind, xc, positions, cfg, None)
                    # sequence-parallel residual (no-op unless act_seq rule
                    # is mapped): the scan carry — which remat saves per
                    # layer — rests seq-sharded over the model axis.
                    y = logical_constraint(y, "batch", "act_seq", "embed")
                    return y, aux
                fn = jax.checkpoint(body) if remat else body
                x, auxs = jax.lax.scan(fn, x, seg_p)
            aux_total = aux_total + jnp.sum(auxs)
        logits = None if skip_head else self._head(params, x)
        return logits, (new_caches if cache is not None else None), aux_total, x

    # ------------------------------------------------------------------
    # Serving steps
    # ------------------------------------------------------------------
    def prefill(self, params, tokens, cache, frontend=None, positions=None):
        logits, new_cache, _, _ = self.forward(params, tokens, frontend, cache, positions=positions)
        return logits[:, -1:], new_cache

    def decode_step(self, params, token, pos, cache):
        """token: [B, 1] int32; pos: [B] absolute positions."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], token).astype(self.dtype)
        x = logical_constraint(x, "batch", None, "embed")
        new_caches: dict = {}
        positions = pos[:, None]
        for si, (kind, count) in enumerate(self.segments):
            if kind == "shared_attn":
                x, nc = blocks.shared_attn_decode(params["shared_attn"], x, pos, cfg, cache[str(si)])
                new_caches[str(si)] = nc
                continue
            seg_p = params["segs"][str(si)]

            def body(xc, inp, _kind=kind):
                p_l, c_l = inp
                y, nc, _ = blocks.block_decode(p_l, _kind, xc, pos, cfg, c_l)
                return y, nc
            x, ncs = jax.lax.scan(body, x, (seg_p, cache[str(si)]))
            new_caches[str(si)] = ncs
        logits = self._head(params, x)
        return logits, new_caches

    # ------------------------------------------------------------------
    # Losses
    # ------------------------------------------------------------------
    def _head_hidden(self, params, x):
        """(normed hidden, head weight, transpose?) for fused chunked CE."""
        cfg = self.cfg
        h = apply_norm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            return h, params["embed"], True
        return h, params["lm_head"], False

    def loss(self, params, batch, remat: bool = False):
        """batch: {tokens [B,S], loss_mask [B,S] opt, frontend opt}.

        Uses the fused chunked head+CE (layers.chunked_ce_from_hidden) — the
        full [B, S, V] logits are never materialized."""
        cfg = self.cfg
        tokens = batch["tokens"]
        frontend = batch.get("frontend")
        _, _, aux, hidden = self.forward(
            params, tokens, frontend, remat=remat, skip_head=True
        )
        n_front = cfg.frontend_tokens
        h, head, transpose = self._head_hidden(params, hidden[:, n_front:-1])
        mask = batch.get("loss_mask")
        mask = mask[:, 1:] if mask is not None else None
        loss = chunked_ce_from_hidden(head, h, tokens[:, 1:], mask, transpose)
        metrics = {"ce": loss, "aux": aux}
        if cfg.num_experts:
            loss = loss + cfg.router_aux_weight * aux
        if cfg.mtp and "mtp" in params:
            mtp_loss = self._mtp_loss(params, hidden[:, n_front:], tokens)
            metrics["mtp"] = mtp_loss
            loss = loss + 0.3 * mtp_loss
        metrics["loss"] = loss
        return loss, metrics

    def _mtp_loss(self, params, hidden, tokens):
        """DeepSeek-V3 depth-1 multi-token prediction: predict t+2 from
        (h_t, emb(tok_{t+1})) through one extra block."""
        cfg = self.cfg
        p = params["mtp"]
        h = apply_norm(p["norm_h"], hidden[:, :-2], cfg.norm_eps)
        e = apply_norm(
            p["norm_e"], embed_tokens(params["embed"], tokens[:, 1:-1]).astype(h.dtype), cfg.norm_eps
        )
        merged = jnp.concatenate([h, e], axis=-1) @ p["proj"]
        b, s, _ = merged.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        mtp_block = jax.checkpoint(
            lambda x: blocks.block_forward(p["block"], "dense", x, positions, cfg, None)[0]
        )
        out = mtp_block(merged)
        h, head, transpose = self._head_hidden(params, out)
        return chunked_ce_from_hidden(head, h, tokens[:, 2:], None, transpose)
