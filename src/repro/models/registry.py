"""Model registry: build a model object from a ModelConfig or arch id."""

from __future__ import annotations

from typing import Union

from repro.models.config import ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.transformer import DecoderLM

Model = Union[DecoderLM, EncDecLM]


def build_model(cfg: ModelConfig) -> Model:
    if cfg.is_encoder_decoder:
        return EncDecLM(cfg)
    return DecoderLM(cfg)


def get_config(arch: str) -> ModelConfig:
    """Resolve an architecture id to its config (see repro.configs)."""
    from repro import configs

    return configs.get(arch)


def build(arch: str) -> Model:
    return build_model(get_config(arch))
