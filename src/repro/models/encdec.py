"""Encoder-decoder transformer (Whisper-style audio backbone, GEN-FUSER).

The encoder consumes either precomputed frontend frame/patch embeddings
(audio — the conv/mel frontend is a stub per spec) or text tokens
(GEN-FUSER).  The decoder is a causal GQA stack with per-layer
cross-attention; cross K/V are computed once from the encoder output and
cached for decoding.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    chunked_ce_from_hidden,
    cross_entropy,
    dense_init,
    embed_init,
    embed_tokens,
    init_embedding,
    init_mlp,
    init_norm,
    lm_logits,
)
from repro.sharding import logical_constraint


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.is_encoder_decoder
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        cfg, dtype = self.cfg, self.dtype
        ks = jax.random.split(key, 8)
        fdim = cfg.frontend_dim or cfg.d_model

        def enc_block(k):
            k1, k2 = jax.random.split(k)
            return {
                "norm1": init_norm(cfg.d_model, dtype, cfg.norm),
                "attn": attn_mod.init_cross_attention(k1, cfg, dtype),
                "norm2": init_norm(cfg.d_model, dtype, cfg.norm),
                "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
            }

        def dec_block(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "norm1": init_norm(cfg.d_model, dtype, cfg.norm),
                "self_attn": attn_mod.init_attention(k1, cfg, dtype),
                "norm_x": init_norm(cfg.d_model, dtype, cfg.norm),
                "cross": attn_mod.init_cross_attention(k2, cfg, dtype),
                "norm2": init_norm(cfg.d_model, dtype, cfg.norm),
                "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
            }

        params = {
            "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dtype),
            "enc_pos": embed_init(ks[1], (max(cfg.enc_seq, 1), cfg.d_model), dtype),
            "frontend_proj": dense_init(ks[2], fdim, (fdim, cfg.d_model), dtype),
            "enc_segs": jax.vmap(enc_block)(jax.random.split(ks[3], cfg.enc_layers)),
            "enc_norm": init_norm(cfg.d_model, dtype, cfg.norm),
            "dec_segs": jax.vmap(dec_block)(jax.random.split(ks[4], cfg.num_layers)),
            "final_norm": init_norm(cfg.d_model, dtype, cfg.norm),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks[5], cfg.d_model, (cfg.d_model, cfg.vocab_size), dtype)
        return params

    # ------------------------------------------------------------------
    def encode(
        self,
        params: dict,
        enc_frontend: Optional[jax.Array] = None,
        enc_tokens: Optional[jax.Array] = None,
    ) -> jax.Array:
        cfg = self.cfg
        if enc_frontend is not None:
            x = enc_frontend.astype(self.dtype) @ params["frontend_proj"]
        else:
            x = embed_tokens(params["embed"], enc_tokens).astype(self.dtype)
        s = x.shape[1]
        x = x + params["enc_pos"][:s][None]
        x = logical_constraint(x, "batch", "seq", "embed")

        def body(xc, p_l):
            h = apply_norm(p_l["norm1"], xc, cfg.norm_eps)
            k, v = attn_mod.cross_kv(p_l["attn"], h)
            xc = xc + attn_mod.cross_attend(p_l["attn"], h, k, v)  # bidirectional self-attn
            h2 = apply_norm(p_l["norm2"], xc, cfg.norm_eps)
            return xc + apply_mlp(p_l["mlp"], h2, cfg.act), None

        x, _ = jax.lax.scan(body, x, params["enc_segs"])
        return apply_norm(params["enc_norm"], x, cfg.norm_eps)

    # ------------------------------------------------------------------
    def _dec_stack(self, params, x, positions, enc_out=None, cache=None, pos=None):
        """Shared decoder stack. Full-seq when positions given; decode when
        ``pos`` given (x is [B,1,D]). cache: {"self": stacked, "ck","cv"}."""
        cfg = self.cfg
        decode = pos is not None
        if cache is not None:
            ck, cv = cache["ck"], cache["cv"]
        else:
            ck = cv = None
        new_self = None
        if decode:
            def body(xc, inp):
                p_l, c_l, k_l, v_l = inp
                h = apply_norm(p_l["norm1"], xc, cfg.norm_eps)
                a, nc = attn_mod.attention_decode(p_l["self_attn"], h, pos, cfg, c_l)
                xc = xc + a
                hx = apply_norm(p_l["norm_x"], xc, cfg.norm_eps)
                xc = xc + attn_mod.cross_attend(p_l["cross"], hx, k_l, v_l)
                h2 = apply_norm(p_l["norm2"], xc, cfg.norm_eps)
                return xc + apply_mlp(p_l["mlp"], h2, cfg.act), nc
            x, new_self = jax.lax.scan(body, x, (params["dec_segs"], cache["self"], ck, cv))
        elif cache is not None:
            def body(xc, inp):
                p_l, c_l = inp
                h = apply_norm(p_l["norm1"], xc, cfg.norm_eps)
                a, nc = attn_mod.attention_forward(p_l["self_attn"], h, positions, cfg, c_l)
                xc = xc + a
                hx = apply_norm(p_l["norm_x"], xc, cfg.norm_eps)
                k_l, v_l = attn_mod.cross_kv(p_l["cross"], enc_out)
                xc = xc + attn_mod.cross_attend(p_l["cross"], hx, k_l, v_l)
                h2 = apply_norm(p_l["norm2"], xc, cfg.norm_eps)
                return xc + apply_mlp(p_l["mlp"], h2, cfg.act), (nc, k_l, v_l)
            x, (new_self, cks, cvs) = jax.lax.scan(body, x, (params["dec_segs"], cache["self"]))
            return x, {"self": new_self, "ck": cks, "cv": cvs}
        else:
            @jax.checkpoint
            def body(xc, p_l):
                h = apply_norm(p_l["norm1"], xc, cfg.norm_eps)
                a, _ = attn_mod.attention_forward(p_l["self_attn"], h, positions, cfg, None)
                xc = xc + a
                hx = apply_norm(p_l["norm_x"], xc, cfg.norm_eps)
                k_l, v_l = attn_mod.cross_kv(p_l["cross"], enc_out)
                xc = xc + attn_mod.cross_attend(p_l["cross"], hx, k_l, v_l)
                h2 = apply_norm(p_l["norm2"], xc, cfg.norm_eps)
                return xc + apply_mlp(p_l["mlp"], h2, cfg.act), None
            x, _ = jax.lax.scan(body, x, params["dec_segs"])
            return x, None
        return x, {"self": new_self, "ck": ck, "cv": cv}

    def _head(self, params, x):
        cfg = self.cfg
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            return lm_logits(params["embed"], x, transpose=True)
        return lm_logits(params["lm_head"], x, transpose=False)

    # ------------------------------------------------------------------
    def forward(self, params, dec_tokens, enc_frontend=None, enc_tokens=None):
        enc_out = self.encode(params, enc_frontend, enc_tokens)
        b, s = dec_tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        x = embed_tokens(params["embed"], dec_tokens).astype(self.dtype)
        x, _ = self._dec_stack(params, x, positions, enc_out=enc_out)
        return self._head(params, x)

    def loss(self, params, batch, remat: bool = False):
        """Fused chunked head+CE — full [B, S, V] logits never materialize."""
        cfg = self.cfg
        dec_tokens = batch["dec_tokens"]
        enc_out = self.encode(
            params, batch.get("enc_frontend"), batch.get("enc_tokens")
        )
        b, s = dec_tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        x = embed_tokens(params["embed"], dec_tokens).astype(self.dtype)
        x, _ = self._dec_stack(params, x, positions, enc_out=enc_out)
        h = apply_norm(params["final_norm"], x, cfg.norm_eps)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        mask = batch.get("loss_mask")
        mask = mask[:, 1:] if mask is not None else None
        loss = chunked_ce_from_hidden(
            head, h[:, :-1], dec_tokens[:, 1:], mask, cfg.tie_embeddings
        )
        return loss, {"ce": loss, "loss": loss}

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, enc_seq: Optional[int] = None) -> dict:
        """``enc_seq`` overrides the config's encoder length so callers that
        serve a fixed (bucketed) encoder shape get cross-K/V buffers whose
        shape round-trips through ``prefill`` — a prerequisite for buffer
        donation in the static-shape fast path (serve.dispatch)."""
        cfg, dtype = self.cfg, self.dtype
        one = attn_mod.init_cache(cfg, batch, max_seq, dtype)
        l, h, hd = cfg.num_layers, cfg.num_heads, cfg.resolved_head_dim
        se = cfg.enc_seq if enc_seq is None else enc_seq
        return {
            "self": jax.tree.map(lambda t: jnp.broadcast_to(t[None], (l,) + t.shape), one),
            "ck": jnp.zeros((l, batch, se, h, hd), dtype),
            "cv": jnp.zeros((l, batch, se, h, hd), dtype),
        }

    def prefill(self, params, dec_tokens, cache, enc_frontend=None, enc_tokens=None):
        enc_out = self.encode(params, enc_frontend, enc_tokens)
        b, s = dec_tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        x = embed_tokens(params["embed"], dec_tokens).astype(self.dtype)
        x, new_cache = self._dec_stack(params, x, positions, enc_out=enc_out, cache=cache)
        return self._head(params, x)[:, -1:], new_cache

    def decode_step(self, params, token, pos, cache):
        x = embed_tokens(params["embed"], token).astype(self.dtype)
        x, new_cache = self._dec_stack(params, x, None, cache=cache, pos=pos)
        return self._head(params, x), new_cache
