"""Mamba2 (SSD — state-space duality) blocks.

Prefill/training uses the chunked SSD algorithm (arXiv:2405.21060):
intra-chunk quadratic "attention" + inter-chunk state recurrence via
``lax.scan``.  Decode is the O(1) recurrent state update.  The per-chunk
inner computation is the compute hot-spot mirrored by the Pallas kernel in
``repro.kernels.ssd_scan``; this module is the pure-JAX production path and
oracle.

Layout conventions (ngroups = 1):
    x   [B, S, nh, hd]   inputs split into SSD heads
    dt  [B, S, nh]       softplus-discretized step sizes
    a   [B, S, nh]       per-step decay = exp(-exp(A_log) * dt)
    Bm  [B, S, N]        input projection (shared across heads)
    Cm  [B, S, N]        output projection (shared across heads)
    h   [B, nh, hd, N]   recurrent state
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_norm, dense_init, init_norm
from repro.sharding import logical_constraint

CHUNK = 128


# ---------------------------------------------------------------------------
# Params / cache
# ---------------------------------------------------------------------------


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def init_ssm(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d, di, n, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads
    proj_out = 2 * di + 2 * n + nh  # z, x, B, C, dt
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], d, (d, proj_out), dtype),
        "conv_w": dense_init(ks[1], cfg.ssm_conv, (cfg.ssm_conv, conv_dim(cfg)), dtype),
        "conv_b": jnp.zeros((conv_dim(cfg),), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": init_norm(di, dtype),
        "out_proj": dense_init(ks[2], di, (di, d), dtype),
    }


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim(cfg)), dtype),
    }


# ---------------------------------------------------------------------------
# Projections shared by all paths
# ---------------------------------------------------------------------------


def _split_proj(p: dict, x: jax.Array, cfg: ModelConfig):
    di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads
    proj = x @ p["in_proj"]
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * n]
    dt_raw = proj[..., di + di + 2 * n :]  # [B,S,nh]
    return z, xbc, dt_raw


def _causal_conv(p: dict, xbc: jax.Array, prev: Optional[jax.Array]):
    """Depthwise causal conv over [B, S, C] with kernel [K, C].

    ``prev``: trailing K-1 inputs from an earlier segment (decode/prefill
    continuation) or None for a fresh zero history.
    """
    k = p["conv_w"].shape[0]
    b = xbc.shape[0]
    if prev is None:
        prev = jnp.zeros((b, k - 1, xbc.shape[-1]), xbc.dtype)
    full = jnp.concatenate([prev, xbc], axis=1)  # [B, S+K-1, C]
    out = sum(full[:, i : i + xbc.shape[1]] * p["conv_w"][i] for i in range(k))
    out = jax.nn.silu(out + p["conv_b"])
    new_prev = full[:, -(k - 1) :] if k > 1 else full[:, :0]
    return out, new_prev


def _discretize(p: dict, dt_raw: jax.Array):
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,nh]
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)  # [B,S,nh]
    return dt, a


def _gated_group_norm(p: dict, y: jax.Array, z: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Mamba2 RMSNormGated with per-SSD-head groups.

    Normalizing over the full d_inner would reduce across the model-sharded
    dim and force a per-layer all-gather of [B,S,d_inner] (measured: the
    dominant collective of zamba2 prefill — EXPERIMENTS.md §Perf A).
    Head-group norm keeps the reduction inside a shard.
    """
    *lead, di = y.shape
    nh, hd = cfg.ssm_num_heads, cfg.ssm_head_dim
    g = (y * jax.nn.silu(z)).reshape(*lead, nh, hd).astype(jnp.float32)
    ms = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(ms + cfg.norm_eps)
    g = g.reshape(*lead, di) * p["gate_norm"]["scale"].astype(jnp.float32)
    return g.astype(y.dtype)


# ---------------------------------------------------------------------------
# Chunked SSD scan (prefill / training)
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: jax.Array,  # [B, S, nh, hd]
    dt: jax.Array,  # [B, S, nh]
    a: jax.Array,  # [B, S, nh]
    Bm: jax.Array,  # [B, S, N]
    Cm: jax.Array,  # [B, S, N]
    h0: Optional[jax.Array] = None,  # [B, nh, hd, N]
    chunk: int = CHUNK,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,nh,hd], h_final [B,nh,hd,N]). Pure-jnp oracle path."""
    b, s, nh, hd = x.shape
    n = Bm.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk

    def rs(t, tail):  # [B, S, ...] -> [nc, B, chunk, ...]
        return t.reshape(b, nc, chunk, *tail).swapaxes(0, 1)

    xs = (rs(x, (nh, hd)), rs(dt, (nh,)), rs(a, (nh,)), rs(Bm, (n,)), rs(Cm, (n,)))
    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, n), jnp.float32)

    def step(h, inp):
        xc, dtc, ac, bc, cc = inp
        y, h_new = _ssd_chunk(xc, dtc, ac, bc, cc, h)
        return h_new, y

    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, nc * chunk, nh, hd)
    return y[:, :s], h_final


def _ssd_chunk(xc, dtc, ac, bc, cc, h_in):
    """One SSD chunk.

    xc [B,L,nh,hd], dtc/ac [B,L,nh], bc/cc [B,L,N], h_in [B,nh,hd,N].
    """
    f32 = jnp.float32
    xc, dtc, ac, bc, cc = (t.astype(f32) for t in (xc, dtc, ac, bc, cc))
    logs = jnp.cumsum(jnp.log(jnp.maximum(ac, 1e-30)), axis=1)  # [B,L,nh] inclusive

    # Intra-chunk: y[l] += sum_{m<=l} prod(a[m+1..l]) * (C_l . B_m) * dt_m * x_m
    # prod(a[m+1..l]) = exp(logs[l] - logs[m]).  Mask BEFORE the exp: the
    # non-causal region has positive exponents that overflow to inf, and
    # grad-of-where turns masked infs into NaN gradients.
    l_idx = jnp.arange(logs.shape[1])
    causal = (l_idx[:, None] >= l_idx[None, :])[None, :, :, None]
    delta = logs[:, :, None, :] - logs[:, None, :, :]  # [B,L(l),L(m),nh]
    w = jnp.exp(jnp.where(causal, delta, -jnp.inf))
    g = jnp.einsum("bln,bmn->blm", cc, bc)  # [B,L,L]
    wdt = w * g[..., None] * dtc[:, None, :, :]  # [B,l,m,nh]
    y = jnp.einsum("blmh,bmhd->blhd", wdt, xc)

    # Contribution of the incoming state: y[l] += C_l . (prod(a[1..l]) * h_in)
    y += jnp.einsum("bln,blh,bhdn->blhd", cc, jnp.exp(logs), h_in)

    # Chunk-final state: h = prod(a over chunk)*h_in + sum_m prod(a[m+1..L]) dt_m B_m x_m
    total = logs[:, -1]  # [B,nh]
    tail = jnp.exp(total[:, None, :] - logs)  # [B,L,nh]
    h_new = jnp.exp(total)[:, :, None, None] * h_in
    h_new += jnp.einsum("blh,bln,blhd->bhdn", tail * dtc, bc, xc)
    return y, h_new


def ssd_reference(x, dt, a, Bm, Cm, h0=None):
    """Naive sequential scan — ground truth for tests."""
    b, s, nh, hd = x.shape
    n = Bm.shape[-1]
    h = jnp.zeros((b, nh, hd, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    ys = []
    for t in range(s):
        upd = jnp.einsum("bh,bhd,bn->bhdn", dt[:, t].astype(jnp.float32),
                         x[:, t].astype(jnp.float32), Bm[:, t].astype(jnp.float32))
        h = a[:, t].astype(jnp.float32)[:, :, None, None] * h + upd
        ys.append(jnp.einsum("bn,bhdn->bhd", Cm[:, t].astype(jnp.float32), h))
    return jnp.stack(ys, axis=1), h


# ---------------------------------------------------------------------------
# Block-level forward / decode
# ---------------------------------------------------------------------------


def ssm_forward(
    p: dict, x: jax.Array, cfg: ModelConfig, cache: Optional[dict] = None
) -> Tuple[jax.Array, Optional[dict]]:
    """Full-sequence Mamba2 block. x: [B, S, D]."""
    b, s, _ = x.shape
    di, n, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads, cfg.ssm_head_dim
    z, xbc, dt_raw = _split_proj(p, x, cfg)
    prev = cache["conv"] if cache is not None else None
    conv_out, new_prev = _causal_conv(p, xbc, prev)
    xin = conv_out[..., :di].reshape(b, s, nh, hd)
    xin = logical_constraint(xin, "batch", "seq", "heads", "head_dim")
    Bm = conv_out[..., di : di + n]
    Cm = conv_out[..., di + n :]
    dt, a = _discretize(p, dt_raw)
    h0 = cache["h"] if cache is not None else None
    y, h_final = ssd_chunked(xin, dt, a, Bm, Cm, h0)
    y = y.astype(x.dtype) + (p["D"].astype(x.dtype))[None, None, :, None] * xin
    y = y.reshape(b, s, di)
    y = _gated_group_norm(p, y, z, cfg)
    out = y @ p["out_proj"]
    new_cache = None
    if cache is not None:
        new_cache = {"h": h_final, "conv": new_prev.astype(cache["conv"].dtype)}
    return out, new_cache


def ssm_decode(p: dict, x: jax.Array, cfg: ModelConfig, cache: dict) -> Tuple[jax.Array, dict]:
    """Single-token recurrent step. x: [B, 1, D]."""
    b = x.shape[0]
    di, n, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads, cfg.ssm_head_dim
    z, xbc, dt_raw = _split_proj(p, x, cfg)
    conv_out, new_prev = _causal_conv(p, xbc, cache["conv"])
    xin = conv_out[:, 0, :di].reshape(b, nh, hd)
    Bm = conv_out[:, 0, di : di + n]
    Cm = conv_out[:, 0, di + n :]
    dt, a = _discretize(p, dt_raw)
    dt, a = dt[:, 0], a[:, 0]  # [B, nh]
    h = cache["h"]
    h = a[:, :, None, None] * h + jnp.einsum(
        "bh,bhd,bn->bhdn", dt, xin.astype(jnp.float32), Bm.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhdn->bhd", Cm.astype(jnp.float32), h).astype(x.dtype)
    y = y + p["D"].astype(x.dtype)[None, :, None] * xin
    y = y.reshape(b, 1, di)
    y = _gated_group_norm(p, y, z, cfg)
    out = y @ p["out_proj"]
    return out, {"h": h, "conv": new_prev.astype(cache["conv"].dtype)}
