"""Decoder block variants: dense (pre-norm / parallel), MoE, Mamba2."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm
from repro.models.moe import apply_moe, init_moe


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_block(key: jax.Array, kind: str, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "mamba":
        return {"norm": init_norm(cfg.d_model, dtype, cfg.norm),
                "ssm": ssm_mod.init_ssm(k1, cfg, dtype)}
    p = {"norm1": init_norm(cfg.d_model, dtype, cfg.norm),
         "attn": attn.init_attention(k1, cfg, dtype)}
    if not cfg.parallel_block:
        p["norm2"] = init_norm(cfg.d_model, dtype, cfg.norm)
    if kind == "dense":
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    elif kind == "moe":
        p["moe"] = init_moe(k2, cfg, dtype)
    else:
        raise ValueError(kind)
    return p


def init_shared_attn(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    """Zamba2-style weight-tied attention block used every ``attn_every`` layers."""
    return {"norm": init_norm(cfg.d_model, dtype, cfg.norm),
            "attn": attn.init_attention(key, cfg, dtype)}


# ---------------------------------------------------------------------------
# Apply — full-sequence (training / prefill)
# ---------------------------------------------------------------------------


def block_forward(
    p: dict,
    kind: str,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "mamba":
        h, new_cache = ssm_mod.ssm_forward(p["ssm"], apply_norm(p["norm"], x, cfg.norm_eps), cfg, cache)
        return x + h, new_cache, aux
    xin = apply_norm(p["norm1"], x, cfg.norm_eps)
    a_out, new_cache = attn.attention_forward(p["attn"], xin, positions, cfg, cache)
    if cfg.parallel_block:
        if kind == "moe":
            m_out, aux = apply_moe(p["moe"], xin, cfg)
        else:
            m_out = apply_mlp(p["mlp"], xin, cfg.act)
        return x + a_out + m_out, new_cache, aux
    x = x + a_out
    xin2 = apply_norm(p["norm2"], x, cfg.norm_eps)
    if kind == "moe":
        m_out, aux = apply_moe(p["moe"], xin2, cfg)
    else:
        m_out = apply_mlp(p["mlp"], xin2, cfg.act)
    return x + m_out, new_cache, aux


def block_decode(
    p: dict,
    kind: str,
    x: jax.Array,
    pos: jax.Array,
    cfg: ModelConfig,
    cache: dict,
) -> Tuple[jax.Array, dict, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    if kind == "mamba":
        h, new_cache = ssm_mod.ssm_decode(p["ssm"], apply_norm(p["norm"], x, cfg.norm_eps), cfg, cache)
        return x + h, new_cache, aux
    xin = apply_norm(p["norm1"], x, cfg.norm_eps)
    a_out, new_cache = attn.attention_decode(p["attn"], xin, pos, cfg, cache)
    if cfg.parallel_block:
        if kind == "moe":
            m_out, aux = apply_moe(p["moe"], xin, cfg)
        else:
            m_out = apply_mlp(p["mlp"], xin, cfg.act)
        return x + a_out + m_out, new_cache, aux
    x = x + a_out
    xin2 = apply_norm(p["norm2"], x, cfg.norm_eps)
    if kind == "moe":
        m_out, aux = apply_moe(p["moe"], xin2, cfg)
    else:
        m_out = apply_mlp(p["mlp"], xin2, cfg.act)
    return x + m_out, new_cache, aux


def shared_attn_forward(p, x, positions, cfg, cache=None):
    xin = apply_norm(p["norm"], x, cfg.norm_eps)
    a_out, new_cache = attn.attention_forward(p["attn"], xin, positions, cfg, cache)
    return x + a_out, new_cache


def shared_attn_decode(p, x, pos, cfg, cache):
    xin = apply_norm(p["norm"], x, cfg.norm_eps)
    a_out, new_cache = attn.attention_decode(p["attn"], xin, pos, cfg, cache)
    return x + a_out, new_cache
