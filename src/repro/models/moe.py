"""Mixture-of-Experts layer.

Production path: expert-parallel execution under ``shard_map`` — expert
weights are sharded over the ``model`` mesh axis, tokens over ``data``.
Each device routes its *local* tokens to its *local* experts with a
capacity-bounded gather/scatter dispatch (no O(T*E*C) one-hot tensors), and
partial outputs are summed over the ``model`` axis with a single psum — the
same collective footprint as a megatron MLP.

Single-device path (tests / smoke configs): identical math with
``E_local == E`` and no psum.

Supports DeepSeek-V3-style shared experts and Arctic-style dense-residual
MLP in parallel with the routed experts.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import activation, apply_mlp, dense_init, init_mlp
from repro.sharding import current_rules, logical_constraint

try:  # jax>=0.4.35 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_moe(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.expert_d_ff
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], d, (d, e), jnp.float32),
        "experts": {
            "wi": dense_init(ks[1], d, (e, d, f), dtype),
            "wg": dense_init(ks[2], d, (e, d, f), dtype),
            "wo": dense_init(ks[3], f, (e, f, d), dtype),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, cfg.num_shared_experts * f, dtype)
    if cfg.dense_residual:
        p["dense"] = init_mlp(ks[5], d, cfg.d_ff, dtype)
    return p


# ---------------------------------------------------------------------------
# Routing + capacity dispatch on local tokens / local experts
# ---------------------------------------------------------------------------


def _dispatch_compute_combine(
    x: jax.Array,  # [T, D] local tokens
    router_w: jax.Array,  # [D, E] (replicated)
    experts: dict,  # wi/wg/wo with leading dim E_local
    cfg: ModelConfig,
    e_offset: jax.Array,  # scalar: first expert id owned locally
    capacity: int,
    axis_name: Optional[str],
    data_axes: Tuple[str, ...] = (),
) -> Tuple[jax.Array, jax.Array]:
    t, d = x.shape
    e_local = experts["wi"].shape[0]
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, top_idx = jax.lax.top_k(probs, cfg.moe_top_k)  # [T, k]
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss over the *global* batch.
    num_e = probs.shape[-1]
    occupancy = jax.nn.one_hot(top_idx[:, 0], num_e, dtype=jnp.float32)
    frac_tokens = jnp.mean(occupancy, axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    for ax in data_axes:
        frac_tokens = jax.lax.pmean(frac_tokens, axis_name=ax)
        frac_probs = jax.lax.pmean(frac_probs, axis_name=ax)
    aux = num_e * jnp.sum(frac_tokens * frac_probs)

    # Capacity-bounded scatter of token ids into [E_local, C] slots.
    slot_tok = jnp.full((e_local, capacity), t, jnp.int32)  # t == padding row
    counts = jnp.zeros((e_local,), jnp.int32)
    choice_meta = []
    tok_ids = jnp.arange(t, dtype=jnp.int32)
    e_range = jnp.arange(e_local, dtype=jnp.int32)
    for j in range(cfg.moe_top_k):
        e_j = top_idx[:, j].astype(jnp.int32) - e_offset
        valid = (e_j >= 0) & (e_j < e_local)
        onehot = ((e_j[:, None] == e_range[None, :]) & valid[:, None]).astype(jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1 + counts[None, :]
        pos_j = jnp.sum(pos * onehot, axis=1)
        counts = counts + jnp.sum(onehot, axis=0)
        keep = valid & (pos_j < capacity)
        dest_e = jnp.where(keep, e_j, 0)
        dest_c = jnp.where(keep, pos_j, capacity)  # capacity slot -> dropped
        slot_tok = slot_tok.at[dest_e, dest_c].set(
            jnp.where(keep, tok_ids, t), mode="drop"
        )
        choice_meta.append((keep, dest_e, jnp.minimum(dest_c, capacity - 1), gates[:, j]))

    # Gather expert inputs and run the gated MLP on all local experts.
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    expert_in = x_pad[slot_tok]  # [E_local, C, D]
    act = activation(cfg.act)
    h = act(jnp.einsum("ecd,edf->ecf", expert_in, experts["wg"])) * jnp.einsum(
        "ecd,edf->ecf", expert_in, experts["wi"]
    )
    expert_out = jnp.einsum("ecf,efd->ecd", h, experts["wo"])  # [E_local, C, D]

    # Combine: gather each choice's slot output back to token order.
    y = jnp.zeros((t, d), jnp.float32)
    for keep, dest_e, dest_c, gate in choice_meta:
        val = expert_out[dest_e, dest_c].astype(jnp.float32)  # [T, D]
        y = y + jnp.where(keep[:, None], gate[:, None] * val, 0.0)

    if axis_name is not None:
        y = jax.lax.psum(y, axis_name=axis_name)
        aux = jax.lax.pmean(aux, axis_name=axis_name)
    return y.astype(x.dtype), aux


def _capacity(tokens_local: int, cfg: ModelConfig) -> int:
    c = int(tokens_local * cfg.moe_top_k / max(cfg.num_experts, 1) * cfg.capacity_factor)
    return max(c, cfg.moe_top_k)


# ---------------------------------------------------------------------------
# Public layer
# ---------------------------------------------------------------------------


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (y, aux_loss). Routed experts + shared/dense branches."""
    b, s, d = x.shape
    rules = current_rules()
    routed, aux = _apply_routed(p, x, cfg, rules)
    y = routed
    if "shared" in p:
        y = y + apply_mlp(p["shared"], x, cfg.act)
    if "dense" in p:
        y = y + apply_mlp(p["dense"], x, cfg.act)
    return y, aux


def _apply_routed(p, x, cfg: ModelConfig, rules) -> Tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    use_spmd = False
    if rules is not None:
        mesh = rules.mesh
        names = set(mesh.axis_names)
        use_spmd = "model" in names and mesh.shape["model"] > 1
    if not use_spmd:
        y, aux = _dispatch_compute_combine(
            flat, p["router"], p["experts"], cfg,
            jnp.int32(0), _capacity(b * s, cfg), axis_name=None,
        )
        return y.reshape(b, s, d), aux

    mesh = rules.mesh
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    data_shards = 1
    for a in data_axes:
        data_shards *= mesh.shape[a]
    model_shards = mesh.shape["model"]
    token_axes = data_axes
    if (b * s) % data_shards != 0:
        # Tiny token counts (e.g. long-context decode, batch=1): replicate
        # tokens over the data axes, still shard experts over ``model``.
        token_axes = ()
        data_shards = 1
    t_local = (b * s) // data_shards
    e_local = cfg.num_experts // model_shards
    cap = _capacity(t_local, cfg)

    batch_spec = token_axes if len(token_axes) > 1 else (token_axes[0] if token_axes else None)
    loc_data_axes = token_axes

    # Expert weights rest FSDP-sharded over the ``expert_fsdp`` axes on
    # their d_model / d_ff dim (ZeRO-3) and are gathered just-in-time
    # inside the shard_map.  An ``expert_fsdp: None`` rules override (small
    # models in serving) turns the gather off entirely.
    conf = rules.rules.get("expert_fsdp")
    if conf is None:
        conf_axes: tuple = ()
    elif isinstance(conf, str):
        conf_axes = (conf,)
    else:
        conf_axes = tuple(conf)
    conf_axes = tuple(a for a in conf_axes if a in mesh.axis_names)

    def fsdp_axes_for(dim: int):
        axes = conf_axes
        while axes:
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if dim % prod == 0:
                return axes
            axes = axes[:-1]
        return ()

    e_shapes = {k: v.shape for k, v in p["experts"].items()}
    gather_axes = {k: fsdp_axes_for(shape[1]) for k, shape in e_shapes.items()}
    expert_specs = {
        k: P("model", (ax if len(ax) > 1 else (ax[0] if ax else None)), None)
        for k, ax in gather_axes.items()
    }

    def local_fn(flat_loc, router_w, experts_loc):
        gathered = {
            k: (jax.lax.all_gather(w, gather_axes[k], axis=1, tiled=True)
                if gather_axes[k] else w)
            for k, w in experts_loc.items()
        }
        e_off = jax.lax.axis_index("model").astype(jnp.int32) * e_local
        return _dispatch_compute_combine(
            flat_loc, router_w, gathered, cfg, e_off, cap,
            axis_name="model", data_axes=loc_data_axes,
        )

    y, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(batch_spec, None), P(), expert_specs),
        out_specs=(P(batch_spec, None), P()),
        check_vma=False,
    )(flat, p["router"], p["experts"])
    return y.reshape(b, s, d), aux


def moe_param_specs(cfg: ModelConfig) -> dict:
    """Logical axes for MoE params (see sharding.api)."""
    specs = {
        "router": ("embed", None),
        "experts": {
            "wi": ("experts", "embed", "expert_mlp"),
            "wg": ("experts", "embed", "expert_mlp"),
            "wo": ("experts", "expert_mlp", "embed"),
        },
    }
    if cfg.num_shared_experts:
        specs["shared"] = {"wi": ("embed", "mlp"), "wg": ("embed", "mlp"), "wo": ("mlp", "embed")}
    if cfg.dense_residual:
        specs["dense"] = {"wi": ("embed", "mlp"), "wg": ("embed", "mlp"), "wo": ("mlp", "embed")}
    return specs
