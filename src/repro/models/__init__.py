from repro.models.config import ModelConfig, validate_config
from repro.models.encdec import EncDecLM
from repro.models.registry import Model, build, build_model, get_config
from repro.models.transformer import DecoderLM, model_segments

__all__ = [
    "ModelConfig",
    "validate_config",
    "DecoderLM",
    "EncDecLM",
    "Model",
    "build",
    "build_model",
    "get_config",
    "model_segments",
]
