"""Common neural-net building blocks (pure-functional JAX)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import logical_constraint

# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, fan_in: int, shape, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key: jax.Array, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(d: int, dtype, kind: str = "rmsnorm") -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        y = (x - mean) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
    }[name]


def glu(x: jax.Array, w: jax.Array, b: jax.Array, v: jax.Array, c: jax.Array) -> jax.Array:
    """Gated Linear Unit (Dauphin et al. 2017): (xW+b) * sigmoid(xV+c)."""
    return (x @ w + b) * jax.nn.sigmoid(x @ v + c)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU-style; used by every dense block and expert)
# ---------------------------------------------------------------------------


def init_mlp(key: jax.Array, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d_model, (d_model, d_ff), dtype),
        "wg": dense_init(k2, d_model, (d_model, d_ff), dtype),
        "wo": dense_init(k3, d_ff, (d_ff, d_model), dtype),
    }


def apply_mlp(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    h = activation(act)(x @ p["wg"]) * (x @ p["wi"])
    h = logical_constraint(h, "batch", "seq", "mlp")
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------


def init_embedding(key: jax.Array, vocab: int, d_model: int, dtype) -> jax.Array:
    return embed_init(key, (vocab, d_model), dtype)


def embed_tokens(table: jax.Array, tokens: jax.Array) -> jax.Array:
    out = jnp.take(table, tokens, axis=0)
    return logical_constraint(out, "batch", "seq", "embed")


def lm_logits(table_or_head: jax.Array, x: jax.Array, transpose: bool) -> jax.Array:
    w = table_or_head.T if transpose else table_or_head
    logits = x @ w.astype(x.dtype)
    return logical_constraint(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, dim]; positions: broadcastable to [..., seq]."""
    dim = x.shape[-1]
    freqs = rope_frequencies(dim, theta)  # [dim/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, dim/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None):
    """Mean token-level cross entropy. logits [..., V], labels [...] int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_ce_from_hidden(
    head: jax.Array,
    x: jax.Array,
    labels: jax.Array,
    mask: Optional[jax.Array],
    transpose: bool,
    chunk: int = 512,
) -> jax.Array:
    """Fused LM-head + cross entropy, chunked over the sequence.

    Never materializes the full [B, S, V] logits: each scan step computes
    one [B, chunk, V] slice (rematerialized in the backward), which keeps
    the CE working set at chunk/S of the naive cost — the standard fused
    linear+CE production trick (e.g. Liger), expressed in pure JAX.

    x: [B, S, D] hidden (post-final-norm); labels: [B, S] targets aligned
    with x (caller shifts); mask: [B, S] or None.
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else None
    if mask is None:
        mask = jnp.pad(jnp.ones((b, s), jnp.float32), ((0, 0), (0, pad)))
    nc = x.shape[1] // chunk
    xs = (
        x.reshape(b, nc, chunk, d).swapaxes(0, 1),
        labels.reshape(b, nc, chunk).swapaxes(0, 1),
        mask.reshape(b, nc, chunk).swapaxes(0, 1),
    )

    @jax.checkpoint
    def body(carry, inp):
        xc, lc, mc = inp
        nll_sum, m_sum = carry
        logits = lm_logits(head, xc, transpose).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        m = mc.astype(jnp.float32)
        return (nll_sum + jnp.sum((logz - gold) * m), m_sum + jnp.sum(m)), None

    (nll, msum), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), xs)
    return nll / jnp.maximum(msum, 1.0)


def huber_loss(pred: jax.Array, target: jax.Array, delta: float = 0.3) -> jax.Array:
    """Huber loss (paper Eq. 8, delta=0.3 per Table 2)."""
    err = jnp.abs(pred.astype(jnp.float32) - target.astype(jnp.float32))
    quad = 0.5 * jnp.square(err)
    lin = delta * (err - 0.5 * delta)
    return jnp.mean(jnp.where(err <= delta, quad, lin))
