"""Architecture configuration for every model family in the framework.

A single :class:`ModelConfig` dataclass describes dense decoders (GQA/MLA),
MoE decoders, SSM (Mamba2) stacks, hybrid (Zamba2) stacks, encoder-decoder
models (Whisper / GEN-FUSER) and VLM backbones (InternVL).  The registry in
``repro.models.registry`` turns a config into a model object.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | encoder
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    parallel_block: bool = False  # command-r style: attn+mlp share input, summed
    act: str = "silu"
    norm: str = "rmsnorm"
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    dtype: str = "float32"

    # --- Multi-head Latent Attention (DeepSeek-V3 / MiniCPM3) ---
    use_mla: bool = False
    q_lora_rank: int = 0  # 0 -> full-rank Q projection
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- Mixture of Experts ---
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (0 -> d_ff)
    first_dense_layers: int = 0  # DeepSeek: leading dense layers
    dense_residual: bool = False  # Arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0  # hybrid: shared attention block every k layers

    # --- Attention variants ---
    sliding_window: int = 0  # 0 -> full causal attention

    # --- Encoder-decoder ---
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    enc_seq: int = 0  # encoder input length (frontend frames / patches)

    # --- Modality frontend stubs (VLM / audio) ---
    frontend_tokens: int = 0  # precomputed patch/frame embeddings prepended
    frontend_dim: int = 0  # 0 -> d_model

    # --- Extras ---
    mtp: bool = False  # DeepSeek multi-token-prediction auxiliary head
    source: str = ""  # citation for the config

    # ------------------------------------------------------------------
    def __post_init__(self) -> None:
        if self.family not in {"dense", "moe", "ssm", "hybrid", "vlm", "audio", "encoder"}:
            raise ValueError(f"unknown family {self.family!r}")
        if self.family != "ssm" and self.num_heads and self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError(f"{self.name}: num_heads must be divisible by num_kv_heads")

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.use_mla:
            return self.qk_nope_dim + self.qk_rope_dim
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads

    @property
    def resolved_v_head_dim(self) -> int:
        if self.use_mla:
            return self.v_head_dim
        return self.resolved_head_dim

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if a 500k-token decode step is sub-quadratic for this arch."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    # ------------------------------------------------------------------
    # Parameter accounting (used by the Kaplan cost model in repro.core.cost)
    # ------------------------------------------------------------------
    def attn_params_per_layer(self) -> int:
        d = self.d_model
        if self.use_mla:
            hd = self.qk_nope_dim + self.qk_rope_dim
            q_in = self.q_lora_rank or d
            p = 0
            if self.q_lora_rank:
                p += d * self.q_lora_rank
            p += q_in * self.num_heads * hd  # q up-projection
            p += d * (self.kv_lora_rank + self.qk_rope_dim)  # kv down + shared rope key
            p += self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
            p += self.num_heads * self.v_head_dim * d  # output proj
            return p
        hd = self.resolved_head_dim
        p = d * self.num_heads * hd  # Q
        p += 2 * d * self.num_kv_heads * hd  # K, V
        p += self.num_heads * hd * d  # O
        if self.qkv_bias:
            p += (self.num_heads + 2 * self.num_kv_heads) * hd
        return p

    def mlp_params(self, hidden: int) -> int:
        # gated (SwiGLU-style) MLP: up, gate, down
        return 3 * self.d_model * hidden

    def ssm_params_per_layer(self) -> int:
        d, di, s = self.d_model, self.d_inner, self.ssm_state
        nh = self.ssm_num_heads
        p = d * (2 * di + 2 * s + nh)  # in_proj -> (x, z, B, C, dt)
        p += self.ssm_conv * (di + 2 * s)  # depthwise conv over x, B, C
        p += nh * 2  # A_log, D
        p += di * d  # out_proj
        return p

    def embedding_params(self) -> int:
        p = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            p *= 2
        return p

    def layer_params(self, layer_idx: int) -> int:
        """Total parameters in decoder layer ``layer_idx``."""
        if self.family == "ssm":
            return self.ssm_params_per_layer()
        if self.family == "hybrid":
            # mamba backbone layer; shared attention counted once in total_params
            return self.ssm_params_per_layer()
        p = self.attn_params_per_layer()
        is_moe = self.num_experts > 0 and layer_idx >= self.first_dense_layers
        if is_moe:
            p += self.num_experts * self.mlp_params(self.expert_d_ff)
            p += self.num_shared_experts * self.mlp_params(self.expert_d_ff)
            p += self.d_model * self.num_experts  # router
            if self.dense_residual:
                p += self.mlp_params(self.d_ff)
        else:
            p += self.mlp_params(self.d_ff)
        return p

    def active_layer_params(self, layer_idx: int) -> int:
        """Parameters touched per token (MoE: only routed top-k experts)."""
        if self.family in ("ssm", "hybrid"):
            return self.layer_params(layer_idx)
        p = self.attn_params_per_layer()
        is_moe = self.num_experts > 0 and layer_idx >= self.first_dense_layers
        if is_moe:
            p += self.moe_top_k * self.mlp_params(self.expert_d_ff)
            p += self.num_shared_experts * self.mlp_params(self.expert_d_ff)
            p += self.d_model * self.num_experts
            if self.dense_residual:
                p += self.mlp_params(self.d_ff)
        else:
            p += self.mlp_params(self.d_ff)
        return p

    def non_embedding_params(self) -> int:
        total = sum(self.layer_params(i) for i in range(self.num_layers))
        if self.family == "hybrid" and self.attn_every:
            total += self.attn_params_per_layer()  # single shared block
        if self.is_encoder_decoder:
            enc_layer = self.attn_params_per_layer() + self.mlp_params(self.d_ff)
            total += self.enc_layers * enc_layer
            total += self.num_layers * self.attn_params_per_layer()  # cross-attn
        return total

    def active_non_embedding_params(self) -> int:
        total = sum(self.active_layer_params(i) for i in range(self.num_layers))
        if self.family == "hybrid" and self.attn_every:
            total += self.attn_params_per_layer()
        if self.is_encoder_decoder:
            enc_layer = self.attn_params_per_layer() + self.mlp_params(self.d_ff)
            total += self.enc_layers * enc_layer
            total += self.num_layers * self.attn_params_per_layer()
        return total

    def total_params(self) -> int:
        return self.non_embedding_params() + self.embedding_params()

    # ------------------------------------------------------------------
    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        small = dict(
            num_layers=2,
            d_model=min(self.d_model, 128),
            vocab_size=min(self.vocab_size, 512),
        )
        if self.num_heads:
            kv = min(self.num_kv_heads, 2)
            heads = max(kv, min(self.num_heads, 4))
            heads -= heads % kv
            small.update(num_heads=heads, num_kv_heads=kv, head_dim=32)
        if self.d_ff:
            small["d_ff"] = min(self.d_ff, 256)
        if self.use_mla:
            small.update(
                q_lora_rank=min(self.q_lora_rank, 64) if self.q_lora_rank else 0,
                kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
                head_dim=0,
            )
        if self.num_experts:
            small.update(num_experts=4, moe_top_k=min(self.moe_top_k, 2),
                         moe_d_ff=64, first_dense_layers=min(self.first_dense_layers, 1))
        if self.ssm_state:
            small.update(ssm_state=16, ssm_head_dim=32)
        if self.attn_every:
            small["attn_every"] = 2
        if self.is_encoder_decoder:
            small.update(enc_layers=2, enc_seq=min(self.enc_seq, 16))
        if self.frontend_tokens:
            small["frontend_tokens"] = 8
        if self.sliding_window:
            small["sliding_window"] = 16
        small["name"] = self.name + "-smoke"
        small.update(overrides)
        return dataclasses.replace(self, **small)


def validate_config(cfg: ModelConfig) -> None:
    assert cfg.num_layers > 0 and cfg.d_model > 0 and cfg.vocab_size > 0
    if cfg.family in ("dense", "moe", "vlm", "audio", "encoder"):
        assert cfg.num_heads > 0
        hd = cfg.resolved_head_dim
        assert hd > 0
    if cfg.use_mla:
        assert cfg.kv_lora_rank > 0 and cfg.qk_rope_dim > 0 and cfg.v_head_dim > 0
    if cfg.num_experts:
        assert cfg.moe_top_k > 0
    if cfg.family in ("ssm", "hybrid"):
        assert cfg.ssm_state > 0
        assert cfg.d_inner % cfg.ssm_head_dim == 0
