"""Attention: GQA (optional bias / sliding window) and MLA (DeepSeek-style
multi-head latent attention), with full-sequence, prefill and single-token
decode paths plus ring-buffer KV caches for long-context serving.

Cache convention
----------------
GQA cache:  {"k": [B, S, KV, hd], "v": [B, S, KV, hd], "pos": [B, S] int32}
MLA cache:  {"ckv": [B, S, rank], "kr": [B, S, rope], "pos": [B, S] int32}

``pos`` stores the absolute position held in each slot (-1 = empty), which
makes a sliding-window ring buffer trivial: slot = position % S, and masking
is purely position-based, so slots can be written out of order.  RoPE is
applied at *write* time with the absolute position, so scores never depend
on slot order.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_norm, apply_rope, dense_init, init_norm
from repro.sharding import current_rules, logical_constraint

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_attention(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    if cfg.use_mla:
        return _init_mla(key, cfg, dtype)
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (d, h, hd), dtype),
        "wk": dense_init(ks[1], d, (d, kv, hd), dtype),
        "wv": dense_init(ks[2], d, (d, kv, hd), dtype),
        "wo": dense_init(ks[3], h * hd, (h, hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    return p


def _init_mla(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    nope, rope, vhd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rank = cfg.kv_lora_rank
    ks = jax.random.split(key, 8)
    p = {
        "wdkv": dense_init(ks[0], d, (d, rank), dtype),
        "wkr": dense_init(ks[1], d, (d, rope), dtype),
        "kv_norm": init_norm(rank, dtype),
        "wuk": dense_init(ks[2], rank, (rank, h, nope), dtype),
        "wuv": dense_init(ks[3], rank, (rank, h, vhd), dtype),
        "wo": dense_init(ks[4], h * vhd, (h, vhd, d), dtype),
    }
    if cfg.q_lora_rank:
        p["wdq"] = dense_init(ks[5], d, (d, cfg.q_lora_rank), dtype)
        p["q_norm"] = init_norm(cfg.q_lora_rank, dtype)
        p["wuq"] = dense_init(ks[6], cfg.q_lora_rank, (cfg.q_lora_rank, h, nope + rope), dtype)
    else:
        p["wuq"] = dense_init(ks[6], d, (d, h, nope + rope), dtype)
    return p


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> dict:
    """Per-layer cache (callers stack over layers)."""
    if cfg.use_mla:
        cache = {
            "ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype),
        }
    else:
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cache = {
            "k": jnp.zeros((batch, max_seq, kv, hd), dtype),
            "v": jnp.zeros((batch, max_seq, kv, hd), dtype),
        }
    cache["pos"] = jnp.full((batch, max_seq), -1, jnp.int32)
    return cache


def cache_slots(cache: dict) -> int:
    return cache["pos"].shape[1]


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, window: int) -> jax.Array:
    """Additive attention bias [B, 1, Sq, Sk] from absolute positions.

    q_pos: [B, Sq]; k_pos: [B, Sk] (-1 marks empty cache slots).
    """
    q = q_pos[:, :, None]
    k = k_pos[:, None, :]
    ok = (k >= 0) & (k <= q)
    if window > 0:
        ok &= k > q - window
    return jnp.where(ok, 0.0, NEG_INF)[:, None, :, :]


# ---------------------------------------------------------------------------
# GQA core
# ---------------------------------------------------------------------------


def _project_qkv(p: dict, x: jax.Array, positions: jax.Array, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta).swapaxes(1, 2)
    k = apply_rope(k.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta).swapaxes(1, 2)
    q = logical_constraint(q, "batch", "seq", "heads", "head_dim")
    k = logical_constraint(k, "batch", "seq", "kv_heads", "head_dim")
    v = logical_constraint(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


# Above this query length, prefill attention runs in query chunks so the
# [Sq, Sk] score matrix never materializes at full size (pure-JAX analogue
# of the flash_attention kernel, used on backends without Pallas lowering).
CHUNKED_PREFILL_THRESHOLD = 4096
PREFILL_CHUNK = 1024


def _expand_kv_if_needed(k, v, num_heads: int):
    """Repeat KV heads up to the full query-head count when the query heads
    divide the mesh's model axis but the KV heads do not (e.g. 96H/8KV on a
    16-wide axis).  The repeated K/V shard over heads, which lets the score
    tensor shard too — otherwise scores replicate at [B,KV,G,Sq,Sk] size
    (measured: the temp blow-up of command-r/qwen prefill_32k, §Perf B)."""
    rules = current_rules()
    if rules is None:
        return k, v, False
    mesh_axes = rules.rules.get("heads")
    if mesh_axes is None:
        return k, v, False
    if isinstance(mesh_axes, str):
        mesh_axes = (mesh_axes,)
    width = 1
    for a in mesh_axes:
        width *= rules.mesh.shape[a]
    num_kv = k.shape[2]
    if num_kv % width == 0 or num_heads % width != 0:
        return k, v, False
    group = num_heads // num_kv
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)
    k = logical_constraint(k, "batch", "seq", "heads", "head_dim")
    v = logical_constraint(v, "batch", "seq", "heads", "head_dim")
    return k, v, True


def _gqa_scores_softmax_out(q, k, v, bias, num_kv: int):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,KV,hd]; bias: [B,1,Sq,Sk] -> [B,Sq,H,hd]."""
    b, sq, h, hd = q.shape
    k, v, expanded = _expand_kv_if_needed(k, v, h)
    if expanded:
        num_kv = h
    group = h // num_kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qg = q.reshape(b, sq, num_kv, group, hd)
    # bf16 operands with f32 accumulation (MXU-native); an explicit
    # .astype(f32) on k/v would materialize an f32 copy of the whole KV
    # cache per decode step (EXPERIMENTS.md §Perf D).
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k, preferred_element_type=jnp.float32)
    # bias [B,1,Sq,Sk] -> [B,1,1,Sq,Sk] so it broadcasts over (kv, group).
    scores = scores * scale + bias[:, :, None].astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgqs,bskh->bqkgh", probs.astype(q.dtype), v, preferred_element_type=jnp.float32
    )
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def _gqa_chunked(q, k, v, q_pos, k_pos, window: int, num_kv: int):
    """Query-chunked causal attention: scan over Sq blocks; per block the
    scores are [B, KV, G, C, Sk] — bounded regardless of Sq."""
    b, sq, h, hd = q.shape
    chunk = PREFILL_CHUNK
    pad = (-sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
    nq = q.shape[1] // chunk
    qs = q.reshape(b, nq, chunk, h, hd).swapaxes(0, 1)  # [nq, B, C, H, hd]
    ps = q_pos.reshape(b, nq, chunk).swapaxes(0, 1)

    def body(_, inp):
        qc, pc = inp
        bias = _mask_bias(pc, k_pos, window)
        out = _gqa_scores_softmax_out(qc, k, v, bias, num_kv)
        return None, out

    _, outs = jax.lax.scan(body, None, (qs, ps))
    out = outs.swapaxes(0, 1).reshape(b, nq * chunk, h, hd)
    return out[:, :sq]


def gqa_forward(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Full-sequence attention (training, or prefill when ``cache`` given)."""
    q, k, v = _project_qkv(p, x, positions, cfg)
    new_cache = None
    if cache is not None:
        new_cache = _write_cache_bulk(cache, {"k": k, "v": v}, positions, cfg.sliding_window)
    if x.shape[1] > CHUNKED_PREFILL_THRESHOLD:
        out = _gqa_chunked(q, k, v, positions, positions, cfg.sliding_window, cfg.num_kv_heads)
    else:
        bias = _mask_bias(positions, positions, cfg.sliding_window)
        out = _gqa_scores_softmax_out(q, k, v, bias, cfg.num_kv_heads)
    out = logical_constraint(out, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def gqa_decode(
    p: dict, x: jax.Array, pos: jax.Array, cfg: ModelConfig, cache: dict
) -> Tuple[jax.Array, dict]:
    """Single-token decode. x: [B,1,D]; pos: [B] absolute positions."""
    positions = pos[:, None]
    q, k, v = _project_qkv(p, x, positions, cfg)
    cache = _write_cache_step(cache, {"k": k[:, 0], "v": v[:, 0]}, pos, cfg.sliding_window)
    bias = _mask_bias(positions, cache["pos"], cfg.sliding_window)
    # flash-decoding style: the cache length is sharded over the model axis;
    # GSPMD turns the softmax/out reductions into partial-softmax collectives.
    ck = logical_constraint(cache["k"], "batch", "cache_seq", "kv_heads", "head_dim")
    cv = logical_constraint(cache["v"], "batch", "cache_seq", "kv_heads", "head_dim")
    out = _gqa_scores_softmax_out(q, ck, cv, bias, cfg.num_kv_heads)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, cache


# ---------------------------------------------------------------------------
# MLA core
# ---------------------------------------------------------------------------


def _mla_q(p: dict, x: jax.Array, positions: jax.Array, cfg: ModelConfig):
    if "wdq" in p:
        qc = apply_norm(p["q_norm"], x @ p["wdq"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", qc, p["wuq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wuq"])
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = apply_rope(
        q[..., cfg.qk_nope_dim :].swapaxes(1, 2), positions[:, None, :], cfg.rope_theta
    ).swapaxes(1, 2)
    # pin head sharding: under sequence-parallel residuals GSPMD otherwise
    # keeps q seq-sharded and computes ALL heads per device (huge scores)
    q_nope = logical_constraint(q_nope, "batch", "seq", "heads", None)
    q_rope = logical_constraint(q_rope, "batch", "seq", "heads", None)
    return q_nope, q_rope


def _mla_latent(p: dict, x: jax.Array, positions: jax.Array, cfg: ModelConfig):
    ckv = apply_norm(p["kv_norm"], x @ p["wdkv"], cfg.norm_eps)  # [B,S,rank]
    kr = apply_rope(x @ p["wkr"], positions, cfg.rope_theta)  # [B,S,rope] shared head
    return ckv, kr


def mla_forward(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Decompressed MLA for full sequences (training / prefill)."""
    q_nope, q_rope = _mla_q(p, x, positions, cfg)
    ckv, kr = _mla_latent(p, x, positions, cfg)
    new_cache = None
    if cache is not None:
        new_cache = _write_cache_bulk(cache, {"ckv": ckv, "kr": kr}, positions, cfg.sliding_window)
    k_nope = jnp.einsum("bsr,rhn->bshn", ckv, p["wuk"])
    v = jnp.einsum("bsr,rhv->bshv", ckv, p["wuv"])
    k_nope = logical_constraint(k_nope, "batch", "seq", "heads", None)
    v = logical_constraint(v, "batch", "seq", "heads", None)
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.qk_nope_dim + cfg.qk_rope_dim, jnp.float32))

    def mla_block(qn, qr, q_pos):
        s = jnp.einsum("bqhn,bshn->bhqs", qn.astype(jnp.float32), k_nope.astype(jnp.float32))
        s += jnp.einsum("bqhr,bsr->bhqs", qr.astype(jnp.float32), kr.astype(jnp.float32))
        bias = _mask_bias(q_pos, positions, cfg.sliding_window)
        probs = jax.nn.softmax(s * scale + bias.astype(jnp.float32), axis=-1)
        return jnp.einsum("bhqs,bshv->bqhv", probs, v.astype(jnp.float32)).astype(x.dtype)

    sq = x.shape[1]
    if sq > CHUNKED_PREFILL_THRESHOLD:
        chunk = PREFILL_CHUNK
        pad = (-sq) % chunk
        qn = jnp.pad(q_nope, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q_nope
        qr = jnp.pad(q_rope, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q_rope
        qp = jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1) if pad else positions
        b = x.shape[0]
        nq = qn.shape[1] // chunk
        xs = (
            qn.reshape(b, nq, chunk, *qn.shape[2:]).swapaxes(0, 1),
            qr.reshape(b, nq, chunk, *qr.shape[2:]).swapaxes(0, 1),
            qp.reshape(b, nq, chunk).swapaxes(0, 1),
        )
        _, outs = jax.lax.scan(lambda c, i: (None, mla_block(*i)), None, xs)
        out = outs.swapaxes(0, 1).reshape(b, nq * chunk, *outs.shape[3:])[:, :sq]
    else:
        out = mla_block(q_nope, q_rope, positions)
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, new_cache


def mla_decode(
    p: dict, x: jax.Array, pos: jax.Array, cfg: ModelConfig, cache: dict
) -> Tuple[jax.Array, dict]:
    """Absorbed-projection MLA decode over the latent cache.

    Scores are computed directly against the compressed latent:
        q_abs = q_nope @ W_uk   (per head, into latent space)
        s     = q_abs . ckv + q_rope . kr
        o     = (softmax(s) @ ckv) @ W_uv
    so the per-step cost is O(S * rank) per head instead of O(S * (nope+v)).
    """
    positions = pos[:, None]
    q_nope, q_rope = _mla_q(p, x, positions, cfg)  # [B,1,H,*]
    ckv, kr = _mla_latent(p, x, positions, cfg)
    cache = _write_cache_step(cache, {"ckv": ckv[:, 0], "kr": kr[:, 0]}, pos, cfg.sliding_window)
    c = logical_constraint(cache["ckv"], "batch", "cache_seq", None).astype(jnp.float32)
    r = logical_constraint(cache["kr"], "batch", "cache_seq", None).astype(jnp.float32)
    q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32), p["wuk"].astype(jnp.float32))
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.qk_nope_dim + cfg.qk_rope_dim, jnp.float32))
    scores = jnp.einsum("bqhr,bsr->bhqs", q_abs, c)
    scores += jnp.einsum("bqhp,bsp->bhqs", q_rope.astype(jnp.float32), r)
    bias = _mask_bias(positions, cache["pos"], cfg.sliding_window)
    probs = jax.nn.softmax(scores * scale + bias.astype(jnp.float32), axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", probs, c)
    out = jnp.einsum("bqhr,rhv->bqhv", o_lat, p["wuv"].astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, cache


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------


def init_cross_attention(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, (d, h, hd), dtype),
        "wk": dense_init(ks[1], d, (d, h, hd), dtype),
        "wv": dense_init(ks[2], d, (d, h, hd), dtype),
        "wo": dense_init(ks[3], h * hd, (h, hd, d), dtype),
    }


def cross_kv(p: dict, enc_out: jax.Array) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v


def cross_attend(p: dict, x: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    scores = jnp.einsum("bqhk,bshk->bhqs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshk->bqhk", probs, v.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# Cache writes
# ---------------------------------------------------------------------------


def _write_cache_bulk(cache: dict, values: dict, positions: jax.Array, window: int) -> dict:
    """Write a full prefill segment. positions: [B, S] absolute; -1 = padding
    (dropped — those slots keep pos=-1 and stay masked).

    Full-cache prefill (window == 0) uses a masked OVERLAY instead of a
    scatter: prompts are right-padded, so slot i holds position i for every
    real token and the write is pure elementwise select — GSPMD partitions
    it cleanly.  The general scatter forced an all-gather of the entire
    global K/V in f32 per layer (measured: the dominant collective of every
    32k prefill — EXPERIMENTS.md §Perf A)."""
    slots = cache_slots(cache)
    b, s = positions.shape
    if window == 0 and s <= slots:
        valid = positions >= 0  # [B, S]
        new = dict(cache)
        for name, val in values.items():
            old = cache[name]
            head = jnp.where(
                valid.reshape(b, s, *([1] * (old.ndim - 2))),
                val.astype(old.dtype), old[:, :s],
            )
            new[name] = jnp.concatenate([head, old[:, s:]], axis=1) if s < slots else head
        pos_head = jnp.where(valid, positions.astype(jnp.int32), cache["pos"][:, :s])
        new["pos"] = (
            jnp.concatenate([pos_head, cache["pos"][:, s:]], axis=1) if s < slots else pos_head
        )
        return new
    # ring buffer (sliding window): scatter by slot = position % slots
    idx = positions % slots
    idx = jnp.where(positions >= 0, idx, slots)  # out-of-range -> dropped
    new = dict(cache)
    batch_idx = jnp.broadcast_to(jnp.arange(b)[:, None], idx.shape)
    for name, val in values.items():
        new[name] = cache[name].at[batch_idx, idx].set(
            val.astype(cache[name].dtype), mode="drop"
        )
    new["pos"] = cache["pos"].at[batch_idx, idx].set(positions.astype(jnp.int32), mode="drop")
    return new


def _write_cache_step(cache: dict, values: dict, pos: jax.Array, window: int) -> dict:
    """Write one token per batch row. values[name]: [B, ...]; pos: [B]."""
    slots = cache_slots(cache)
    idx = pos % slots if window > 0 else pos
    new = dict(cache)
    b = pos.shape[0]
    batch_idx = jnp.arange(b)
    for name, val in values.items():
        new[name] = cache[name].at[batch_idx, idx].set(val.astype(cache[name].dtype))
    new["pos"] = cache["pos"].at[batch_idx, idx].set(pos.astype(jnp.int32))
    return new


# ---------------------------------------------------------------------------
# Dispatch helpers
# ---------------------------------------------------------------------------


def attention_forward(p, x, positions, cfg: ModelConfig, cache=None):
    if cfg.use_mla:
        return mla_forward(p, x, positions, cfg, cache)
    return gqa_forward(p, x, positions, cfg, cache)


def attention_decode(p, x, pos, cfg: ModelConfig, cache):
    if cfg.use_mla:
        return mla_decode(p, x, pos, cfg, cache)
    return gqa_decode(p, x, pos, cfg, cache)
