"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_with_warmup(peak: float, warmup: int, total: int, floor: float = 0.0):
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return schedule
