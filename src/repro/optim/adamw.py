"""AdamW with decoupled weight decay (paper Table 2: Adam lr 3e-4,
betas (0.9, 0.98), weight decay 0.01)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.98
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: Optional[float] = 1.0

    def init(self, params: Any) -> OptState:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def _lr(self, step: jax.Array) -> jax.Array:
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads: Any, state: OptState, params: Any) -> Tuple[Any, OptState]:
        if self.grad_clip is not None:
            grads = clip_by_global_norm(grads, self.grad_clip)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, OptState(step=step, mu=mu, nu=nu)


def clip_by_global_norm(grads: Any, max_norm: float) -> Any:
    sq = jax.tree.reduce(
        lambda acc, g: acc + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, jnp.zeros(())
    )
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)
