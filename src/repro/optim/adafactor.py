"""Adafactor (Shazeer & Stern 2018) — factored second moments, no first
moment.  Used for the giant MoE configs where full Adam state does not fit
a v5e's 16 GB (DESIGN.md; EXPERIMENTS.md §Dry-run): state is O(rows+cols)
per matrix instead of O(rows*cols).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class FactoredSlot(NamedTuple):
    vr: jax.Array  # mean of squares over the last dim   [..., rows]
    vc: jax.Array  # mean of squares over the 2nd-to-last [..., cols]


class AdafactorState(NamedTuple):
    step: jax.Array
    slots: Any  # pytree: FactoredSlot for >=2D leaves, full v for 1D


@dataclasses.dataclass(frozen=True)
class Adafactor:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 1e-3
    decay: float = 0.99
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    grad_clip: Optional[float] = 1.0

    def _factored(self, p) -> bool:
        return p.ndim >= 2

    def init(self, params: Any) -> AdafactorState:
        def slot(p):
            if self._factored(p):
                return FactoredSlot(
                    vr=jnp.zeros(p.shape[:-1], jnp.float32),
                    vc=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                )
            return jnp.zeros_like(p, dtype=jnp.float32)

        return AdafactorState(step=jnp.zeros((), jnp.int32), slots=jax.tree.map(slot, params))

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads: Any, state: AdafactorState, params: Any):
        from repro.optim.adamw import clip_by_global_norm

        if self.grad_clip is not None:
            grads = clip_by_global_norm(grads, self.grad_clip)
        step = state.step + 1
        lr = self._lr(step)
        b2 = self.decay

        def upd_factored(p, g, vr_in, vc_in):
            """One (possibly layer-sliced) factored update. Never materializes
            the full outer-product V: u = g * rsqrt(vr') * rsqrt(vc') * sqrt(rmean)
            fuses into an elementwise chain (one fp32 temp the size of g)."""
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + self.eps
            vr = b2 * vr_in + (1 - b2) * jnp.mean(g2, axis=-1)
            vc = b2 * vc_in + (1 - b2) * jnp.mean(g2, axis=-2)
            rmean = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), self.eps)
            u = (
                g
                * jax.lax.rsqrt(jnp.maximum(vr, self.eps))[..., :, None]
                * jax.lax.rsqrt(jnp.maximum(vc, self.eps))[..., None, :]
                * jnp.sqrt(rmean)[..., None]
            )
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), vr, vc

        def upd(p, g, slot):
            if self._factored(p):
                # NOTE: measured (EXPERIMENTS.md §Perf): a lax.map over the
                # leading stacked dim COSTS ~4x leaf size in scan buffers,
                # while the direct elementwise chain fuses to zero temps.
                new_p, vr, vc = upd_factored(p, g, slot.vr, slot.vc)
                return new_p, FactoredSlot(vr=vr, vc=vc)
            g32 = g.astype(jnp.float32)
            v = b2 * slot + (1 - b2) * (jnp.square(g32) + self.eps)
            u = g32 * jax.lax.rsqrt(v + self.eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state.slots)
        outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_slots = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_params, AdafactorState(step=step, slots=new_slots)
