from repro.optim.adamw import AdamW, OptState, clip_by_global_norm
from repro.optim.schedule import constant_lr, cosine_with_warmup

__all__ = ["AdamW", "OptState", "clip_by_global_norm", "constant_lr", "cosine_with_warmup"]
