from repro.train import checkpoint
from repro.train.trainer import TrainResult, repeat_batches, train

__all__ = ["checkpoint", "TrainResult", "repeat_batches", "train"]
