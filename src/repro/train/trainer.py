"""Generic training loop used by every trainable component (pool members,
BARTScore scorer, GEN-FUSER, MODI predictor)."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.optim import AdamW, OptState


@dataclasses.dataclass
class TrainResult:
    params: Any
    opt_state: OptState
    history: list


def train(
    loss_fn: Callable,  # (params, batch, rng|None) -> (loss, metrics)
    params: Any,
    batches: Iterator[Dict[str, Any]],
    steps: int,
    optimizer: Optional[AdamW] = None,
    rng: Optional[jax.Array] = None,
    log_every: int = 50,
    log_fn: Callable[[str], None] = print,
    donate: bool = True,
) -> TrainResult:
    optimizer = optimizer or AdamW()
    opt_state = optimizer.init(params)
    use_rng = rng is not None

    def step_fn(params, opt_state, batch, step_rng):
        if use_rng:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch, step_rng
            )
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, metrics

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())
    history = []
    t0 = time.time()
    it = iter(batches)
    for step in range(steps):
        try:
            batch = next(it)
        except StopIteration:
            break
        if use_rng:
            rng, step_rng = jax.random.split(rng)
        else:
            step_rng = None
        params, opt_state, metrics = jit_step(params, opt_state, batch, step_rng)
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step, **m})
            log_fn(f"  step {step:4d}  " + "  ".join(f"{k}={v:.4f}" for k, v in m.items()))
    _ = time.time() - t0
    return TrainResult(params=params, opt_state=opt_state, history=history)


def repeat_batches(make_iter: Callable[[int], Iterable]) -> Iterator:
    """Cycle a (re-seedable) batch iterator forever."""
    epoch = 0
    while True:
        yielded = False
        for b in make_iter(epoch):
            yielded = True
            yield b
        epoch += 1
        if not yielded:
            return
