"""Dependency-free checkpointing: pytree -> .npz + tree-structure JSON."""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, tree: Any) -> None:
    """Save a pytree of arrays to ``path`` (.npz) + ``path + .tree.json``."""
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **arrays)
    with open(path + ".tree.json", "w") as f:
        json.dump({"treedef": str(treedef), "n": len(leaves)}, f)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    if not path.endswith(".npz"):
        path = path + ".npz" if os.path.exists(path + ".npz") else path
    data = np.load(path)
    leaves, treedef = _flatten(like)
    loaded = [data[f"leaf_{i}"] for i in range(len(leaves))]
    for got, want in zip(loaded, leaves):
        if got.shape != want.shape:
            raise ValueError(f"shape mismatch: {got.shape} vs {want.shape}")
    import jax.numpy as jnp

    return jax.tree.unflatten(treedef, [jnp.asarray(g, x.dtype) for g, x in zip(loaded, leaves)])


def exists(path: str) -> bool:
    return os.path.exists(path) or os.path.exists(path + ".npz")
