"""MODI orchestration policy and the baseline selection policies it is
compared against (paper §1 related work, §3 baselines).

A *policy* maps per-query quality estimates and costs to a subset of the
pool.  Generation and fusion of the selected models' responses happen in
``repro.serve.engine``; policies are pure selection logic so they can be
unit-tested and benchmarked in isolation.

Policies are also registered by name in a :class:`PolicyRegistry` so the
serving engine, benchmarks, and CLI flags can construct any of them
uniformly (``make_policy("modi", budget=0.2)``) — including per-request
policy/budget selection in ``repro.serve``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.epsilon import EpsilonConstraint, select_under_budget


class SelectionPolicy:
    name: str = "base"

    def select(self, quality: jax.Array, costs: jax.Array) -> jax.Array:
        """quality/costs: [Q, N] -> bool mask [Q, N]."""
        raise NotImplementedError


@dataclasses.dataclass
class ModiPolicy(SelectionPolicy):
    """The paper's method: epsilon-constrained 0/1 knapsack on predicted
    quality (alpha-shifted) with bucketized Kaplan costs.

    Serving guard (beyond-paper): if ε is below even the cheapest member's
    cost the knapsack returns the empty set — we fall back to the cheapest
    member so every query gets an answer."""

    eps: EpsilonConstraint
    impl: str = "lax"  # bitmask-DP backend: "lax" or "pallas" (TPU kernel)
    name: str = "modi"

    def select(self, quality, costs):
        mask = select_under_budget(quality, costs, self.eps, impl=self.impl)
        costs = jnp.asarray(costs, jnp.float32)
        cheapest = jax.nn.one_hot(jnp.argmin(costs, axis=1), costs.shape[1], dtype=bool)
        empty = ~jnp.any(mask, axis=1, keepdims=True)
        return jnp.where(empty, cheapest, mask)


@dataclasses.dataclass
class FullEnsemblePolicy(SelectionPolicy):
    """LLM-BLENDER's selection: query every model (cost O(N))."""

    name: str = "llm-blender"

    def select(self, quality, costs):
        return jnp.ones_like(jnp.asarray(quality), bool)


@dataclasses.dataclass
class RandomPolicy(SelectionPolicy):
    """Random ensemble of k members (paper Table 1 'Random')."""

    k: int
    seed: int = 0
    name: str = "random"

    def select(self, quality, costs):
        quality = jnp.asarray(quality)
        q, n = quality.shape
        # independent subkey per query, derived from a fingerprint of the
        # query's quality and cost rows (not its batch position) so the draw
        # is invariant to how requests are micro-batched; exact uint32
        # arithmetic over the float bit patterns avoids the collisions a
        # float32 sum would have
        row = jnp.concatenate(
            [jnp.asarray(quality, jnp.float32), jnp.asarray(costs, jnp.float32)],
            axis=1,
        )
        bits = jax.lax.bitcast_convert_type(row, jnp.uint32)
        mult = (jnp.arange(1, 2 * n + 1, dtype=jnp.uint32) * jnp.uint32(2654435761)
                | jnp.uint32(1))
        fp = jnp.sum(bits * mult, axis=1, dtype=jnp.uint32)
        base = jax.random.key(self.seed)
        keys = jax.vmap(lambda f: jax.random.fold_in(base, f))(fp)
        scores = jax.vmap(lambda k: jax.random.uniform(k, (n,)))(keys)
        # exactly-k top-k mask: `scores >= kth` over-selects on ties, so rank
        # instead of thresholding
        top = jnp.argsort(-scores, axis=1)[:, : self.k]
        mask = jnp.zeros((q, n), bool)
        return mask.at[jnp.arange(q)[:, None], top].set(True)


@dataclasses.dataclass
class BestSinglePolicy(SelectionPolicy):
    """Route to the single highest-predicted-quality model."""

    name: str = "best-single"

    def select(self, quality, costs):
        quality = jnp.asarray(quality)
        return jax.nn.one_hot(jnp.argmax(quality, axis=1), quality.shape[1], dtype=bool)


@dataclasses.dataclass
class FixedSinglePolicy(SelectionPolicy):
    """Always model i (per-model rows of Table 1)."""

    index: int
    name: str = "single"

    def select(self, quality, costs):
        quality = jnp.asarray(quality)
        mask = jnp.zeros(quality.shape, bool)
        return mask.at[:, self.index].set(True)


@dataclasses.dataclass
class GreedyRatioPolicy(SelectionPolicy):
    """FrugalGPT-flavoured greedy: add models by profit/cost ratio until the
    budget is exhausted (the classic knapsack approximation; shows what the
    exact DP buys)."""

    eps: EpsilonConstraint
    name: str = "greedy-ratio"

    def select(self, quality, costs):
        quality = np.asarray(quality, np.float64)
        costs = np.asarray(costs, np.float64)
        qn, n = quality.shape
        profits = quality - quality.min() + 1e-6  # shift positive
        budget = self.eps.fraction * costs.sum(axis=1)
        mask = np.zeros((qn, n), bool)
        order = np.argsort(-(profits / np.maximum(costs, 1e-9)), axis=1)
        for qi in range(qn):
            spent = 0.0
            for i in order[qi]:
                if spent + costs[qi, i] <= budget[qi]:
                    mask[qi, i] = True
                    spent += costs[qi, i]
        return jnp.asarray(mask)


@dataclasses.dataclass
class HybridRouterPolicy(SelectionPolicy):
    """Hybrid-LLM-style (Anonymous 2023b): binary routing between the
    cheapest and the best model by predicted difficulty (quality gap)."""

    small_index: int
    large_index: int
    threshold: float = 0.0
    name: str = "hybrid-router"

    def select(self, quality, costs):
        quality = jnp.asarray(quality)
        gap = quality[:, self.large_index] - quality[:, self.small_index]
        use_large = gap > self.threshold
        q, n = quality.shape
        mask = jnp.zeros((q, n), bool)
        mask = mask.at[:, self.small_index].set(~use_large)
        mask = mask.at[:, self.large_index].set(use_large)
        return mask


def realized_cost_fraction(mask: jax.Array, costs: jax.Array) -> jax.Array:
    """Fraction of the full-ensemble (LLM-BLENDER) cost actually spent.

    Rows whose total cost is zero (empty/degenerate cost rows) report a
    fraction of 0 rather than dividing by zero into NaN."""
    costs = jnp.asarray(costs, jnp.float32)
    spent = jnp.sum(jnp.where(mask, costs, 0.0), axis=1)
    total = jnp.sum(costs, axis=1)
    return jnp.where(total > 0, spent / jnp.where(total > 0, total, 1.0), 0.0)


# ---------------------------------------------------------------------------
# Policy registry: string-keyed construction of every built-in policy
# ---------------------------------------------------------------------------


class PolicyRegistry:
    """String-keyed factory for selection policies.

    Every factory accepts an optional ``budget`` kwarg (fraction of the
    full-ensemble cost); budget-insensitive policies ignore it, so a
    per-request budget override can be applied uniformly to any policy
    name (``registry.make("random", budget=0.1)`` is valid and simply
    selects k random members).
    """

    def __init__(self):
        self._factories: Dict[str, Callable[..., SelectionPolicy]] = {}

    def register(self, name: str, factory: Callable[..., SelectionPolicy]) -> None:
        if name in self._factories:
            raise ValueError(f"policy {name!r} already registered")
        self._factories[name] = factory

    def names(self) -> List[str]:
        return sorted(self._factories)

    def make(self, name: str, **kwargs) -> SelectionPolicy:
        try:
            factory = self._factories[name]
        except KeyError:
            raise KeyError(
                f"unknown policy {name!r}; available: {', '.join(self.names())}"
            ) from None
        return factory(**kwargs)


def _eps(eps: Optional[EpsilonConstraint], budget: Optional[float], buckets: int) -> EpsilonConstraint:
    if eps is not None:
        return eps if budget is None else EpsilonConstraint(budget, eps.buckets)
    return EpsilonConstraint(0.2 if budget is None else budget, buckets)


def _make_modi(eps: Optional[EpsilonConstraint] = None, budget: Optional[float] = None,
               buckets: int = 256, impl: str = "lax") -> SelectionPolicy:
    return ModiPolicy(_eps(eps, budget, buckets), impl=impl)


def _make_greedy_ratio(eps: Optional[EpsilonConstraint] = None, budget: Optional[float] = None,
                       buckets: int = 256) -> SelectionPolicy:
    return GreedyRatioPolicy(_eps(eps, budget, buckets))


def _make_full(budget: Optional[float] = None) -> SelectionPolicy:
    return FullEnsemblePolicy()


def _make_random(k: int = 3, seed: int = 0, budget: Optional[float] = None) -> SelectionPolicy:
    return RandomPolicy(k=k, seed=seed)


def _make_best_single(budget: Optional[float] = None) -> SelectionPolicy:
    return BestSinglePolicy()


def _make_single(index: int = 0, budget: Optional[float] = None) -> SelectionPolicy:
    return FixedSinglePolicy(index=index)


def _make_hybrid_router(small_index: int = 0, large_index: int = 1, threshold: float = 0.0,
                        budget: Optional[float] = None) -> SelectionPolicy:
    return HybridRouterPolicy(small_index=small_index, large_index=large_index,
                              threshold=threshold)


DEFAULT_REGISTRY = PolicyRegistry()
DEFAULT_REGISTRY.register("modi", _make_modi)
DEFAULT_REGISTRY.register("greedy-ratio", _make_greedy_ratio)
DEFAULT_REGISTRY.register("llm-blender", _make_full)
DEFAULT_REGISTRY.register("random", _make_random)
DEFAULT_REGISTRY.register("best-single", _make_best_single)
DEFAULT_REGISTRY.register("single", _make_single)
DEFAULT_REGISTRY.register("hybrid-router", _make_hybrid_router)


def make_policy(name: str, **kwargs) -> SelectionPolicy:
    """Construct a policy by registry name, e.g. ``make_policy("modi", budget=0.2)``."""
    return DEFAULT_REGISTRY.make(name, **kwargs)


def available_policies() -> List[str]:
    """Names accepted by :func:`make_policy`."""
    return DEFAULT_REGISTRY.names()
