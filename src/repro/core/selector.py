"""MODI orchestration policy and the baseline selection policies it is
compared against (paper §1 related work, §3 baselines).

A *policy* maps per-query quality estimates and costs to a subset of the
pool.  Generation and fusion of the selected models' responses happen in
``repro.serve.engine``; policies are pure selection logic so they can be
unit-tested and benchmarked in isolation.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.epsilon import EpsilonConstraint, select_under_budget


class SelectionPolicy:
    name: str = "base"

    def select(self, quality: jax.Array, costs: jax.Array) -> jax.Array:
        """quality/costs: [Q, N] -> bool mask [Q, N]."""
        raise NotImplementedError


@dataclasses.dataclass
class ModiPolicy(SelectionPolicy):
    """The paper's method: epsilon-constrained 0/1 knapsack on predicted
    quality (alpha-shifted) with bucketized Kaplan costs.

    Serving guard (beyond-paper): if ε is below even the cheapest member's
    cost the knapsack returns the empty set — we fall back to the cheapest
    member so every query gets an answer."""

    eps: EpsilonConstraint
    name: str = "modi"

    def select(self, quality, costs):
        mask = select_under_budget(quality, costs, self.eps)
        costs = jnp.asarray(costs, jnp.float32)
        cheapest = jax.nn.one_hot(jnp.argmin(costs, axis=1), costs.shape[1], dtype=bool)
        empty = ~jnp.any(mask, axis=1, keepdims=True)
        return jnp.where(empty, cheapest, mask)


@dataclasses.dataclass
class FullEnsemblePolicy(SelectionPolicy):
    """LLM-BLENDER's selection: query every model (cost O(N))."""

    name: str = "llm-blender"

    def select(self, quality, costs):
        return jnp.ones_like(jnp.asarray(quality), bool)


@dataclasses.dataclass
class RandomPolicy(SelectionPolicy):
    """Random ensemble of k members (paper Table 1 'Random')."""

    k: int
    seed: int = 0
    name: str = "random"

    def select(self, quality, costs):
        q, n = jnp.asarray(quality).shape
        rng = jax.random.key(self.seed)
        scores = jax.random.uniform(rng, (q, n))
        kth = jnp.sort(scores, axis=1)[:, n - self.k][:, None]
        return scores >= kth


@dataclasses.dataclass
class BestSinglePolicy(SelectionPolicy):
    """Route to the single highest-predicted-quality model."""

    name: str = "best-single"

    def select(self, quality, costs):
        quality = jnp.asarray(quality)
        return jax.nn.one_hot(jnp.argmax(quality, axis=1), quality.shape[1], dtype=bool)


@dataclasses.dataclass
class FixedSinglePolicy(SelectionPolicy):
    """Always model i (per-model rows of Table 1)."""

    index: int
    name: str = "single"

    def select(self, quality, costs):
        quality = jnp.asarray(quality)
        mask = jnp.zeros(quality.shape, bool)
        return mask.at[:, self.index].set(True)


@dataclasses.dataclass
class GreedyRatioPolicy(SelectionPolicy):
    """FrugalGPT-flavoured greedy: add models by profit/cost ratio until the
    budget is exhausted (the classic knapsack approximation; shows what the
    exact DP buys)."""

    eps: EpsilonConstraint
    name: str = "greedy-ratio"

    def select(self, quality, costs):
        quality = np.asarray(quality, np.float64)
        costs = np.asarray(costs, np.float64)
        qn, n = quality.shape
        profits = quality - quality.min() + 1e-6  # shift positive
        budget = self.eps.fraction * costs.sum(axis=1)
        mask = np.zeros((qn, n), bool)
        order = np.argsort(-(profits / np.maximum(costs, 1e-9)), axis=1)
        for qi in range(qn):
            spent = 0.0
            for i in order[qi]:
                if spent + costs[qi, i] <= budget[qi]:
                    mask[qi, i] = True
                    spent += costs[qi, i]
        return jnp.asarray(mask)


@dataclasses.dataclass
class HybridRouterPolicy(SelectionPolicy):
    """Hybrid-LLM-style (Anonymous 2023b): binary routing between the
    cheapest and the best model by predicted difficulty (quality gap)."""

    small_index: int
    large_index: int
    threshold: float = 0.0
    name: str = "hybrid-router"

    def select(self, quality, costs):
        quality = jnp.asarray(quality)
        gap = quality[:, self.large_index] - quality[:, self.small_index]
        use_large = gap > self.threshold
        q, n = quality.shape
        mask = jnp.zeros((q, n), bool)
        mask = mask.at[:, self.small_index].set(~use_large)
        mask = mask.at[:, self.large_index].set(use_large)
        return mask


def realized_cost_fraction(mask: jax.Array, costs: jax.Array) -> jax.Array:
    """Fraction of the full-ensemble (LLM-BLENDER) cost actually spent."""
    costs = jnp.asarray(costs, jnp.float32)
    return jnp.sum(jnp.where(mask, costs, 0.0), axis=1) / jnp.sum(costs, axis=1)
