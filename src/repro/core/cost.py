"""Kaplan et al. (2020) inference-cost model (paper §2.1).

    c_forward ≈ 2·N + 2·n_layer·n_ctx·d_model   [FLOPs per token]

where N is non-embedding parameters.  The paper's cost objective is
``sum_i c_i · t_i(q)`` over the selected subset; ``t_i`` maps a query to the
expected token count under model i.  For MoE members we use *activated*
non-embedding parameters (extension noted in DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Per-model FLOPs/token cost, Kaplan-style."""

    name: str
    params_active: int  # activated non-embedding params
    n_layer: int
    d_model: int

    def flops_per_token(self, n_ctx: int) -> float:
        return 2.0 * self.params_active + 2.0 * self.n_layer * n_ctx * self.d_model

    def query_cost(self, n_ctx: int, n_tokens: float) -> float:
        """Total FLOPs to answer a query: tokens generated x cost/token."""
        return self.flops_per_token(n_ctx) * float(n_tokens)


def cost_model_from_config(cfg: ModelConfig) -> CostModel:
    return CostModel(
        name=cfg.name,
        params_active=cfg.active_non_embedding_params(),
        n_layer=cfg.num_layers + (cfg.enc_layers if cfg.is_encoder_decoder else 0),
        d_model=cfg.d_model,
    )


def pool_costs(
    cfgs: Sequence[ModelConfig], n_ctx: int, tokens_per_query: Mapping[str, float] | float
) -> np.ndarray:
    """FLOPs cost vector for one query across a pool."""
    out = []
    for cfg in cfgs:
        cm = cost_model_from_config(cfg)
        t = tokens_per_query if isinstance(tokens_per_query, (int, float)) else tokens_per_query[cfg.name]
        out.append(cm.query_cost(n_ctx, t))
    return np.asarray(out, np.float64)


def normalize_costs(costs: np.ndarray, budget: float, buckets: int = 256):
    """Discretize FLOPs costs into integer knapsack weights.

    The paper's Algorithm 1 indexes the DP table by integer cost; real FLOP
    counts are ~1e12, so we quantize weights to ``buckets`` levels of the
    budget.  Ceil-rounding keeps the constraint conservative (never exceeds
    the true budget).  Returns (int_costs, int_budget).
    """
    scale = budget / buckets
    int_costs = np.ceil(np.asarray(costs, np.float64) / scale).astype(np.int64)
    int_costs = np.maximum(int_costs, 1)
    return int_costs, int(buckets)
