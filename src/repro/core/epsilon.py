"""The bi-objective problem and its ε-constraint reduction (paper §2.1-2.2).

Objectives over a subset H of the pool M:
    max  Σ_{m∈H} r(m, q)            (quality, Eq. 2)
    min  Σ_{m∈H} c_i · t_i(q)       (cost, Eq. 1)

ε-constraint (Haimes & Wismer 1971): fix a per-query budget ε on cost and
maximize quality subject to it — a 0/1 knapsack (Eq. 3).  Sweeping ε traces
the Pareto frontier of the bi-objective problem.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.knapsack import knapsack_select, shift_scores


@dataclasses.dataclass(frozen=True)
class EpsilonConstraint:
    """A per-query FLOPs budget, expressed as in the paper's experiments:
    a fraction of the cost of an LLM-BLENDER response (= querying the whole
    pool)."""

    fraction: float  # of full-ensemble cost
    buckets: int = 256  # DP cost discretization

    def budget_flops(self, query_costs: np.ndarray) -> float:
        return float(self.fraction * np.sum(query_costs))


def select_under_budget(
    quality: jax.Array,  # [Q, N] predicted scores (may be negative, BARTScore-like)
    costs_flops: jax.Array,  # [Q, N] per-query FLOPs
    eps: EpsilonConstraint,
    impl: str = "lax",
) -> jax.Array:
    """MODI's selection step: alpha-shift scores, bucketize costs, knapsack.

    ``impl`` picks the bitmask-DP backend: ``"lax"`` (batched jittable
    loop, the serving default) or ``"pallas"`` (the VMEM-resident TPU
    kernel in ``repro.kernels.knapsack``).  Both produce identical
    selections."""
    quality = jnp.asarray(quality, jnp.float32)
    # FLOP counts up to ~1e15 are exactly representable enough for bucketing
    costs_flops = jnp.asarray(costs_flops, jnp.float32)
    profits, _ = shift_scores(quality)
    budget_flops = eps.fraction * jnp.sum(costs_flops, axis=1, keepdims=True)  # [Q,1]
    scale = budget_flops / eps.buckets
    # a zero-cost row (empty/degenerate pool costs) would make scale 0 and
    # NaN the whole mask; every member is free there, so any scale works
    scale = jnp.where(scale > 0, scale, 1.0)
    int_costs = jnp.ceil(costs_flops / scale).astype(jnp.int32)
    int_costs = jnp.maximum(int_costs, 1)
    if impl == "pallas":
        from repro.kernels.knapsack import knapsack_select_pallas

        return knapsack_select_pallas(profits, int_costs, eps.buckets)
    if impl != "lax":
        raise ValueError(f"unknown knapsack impl {impl!r}; expected 'lax' or 'pallas'")
    return knapsack_select(profits, int_costs, eps.buckets)


def pareto_sweep(
    quality: np.ndarray,  # [N] true or predicted per-model scores for one query
    costs: np.ndarray,  # [N] FLOPs
    fractions: Sequence[float] = tuple(np.linspace(0.05, 1.0, 20)),
    buckets: int = 256,
) -> List[Tuple[float, float, np.ndarray]]:
    """ε-sweep for one query: [(cost_fraction, total_quality, mask)] —
    the achievable quality-cost frontier (paper §2.2 motivation)."""
    out = []
    q = jnp.asarray(quality)[None, :]
    c = jnp.asarray(costs, jnp.float32)[None, :]
    # dominance is judged on the alpha-shifted profits the knapsack
    # optimizes (Eq. 4) — raw BARTScores are negative, so the raw sum would
    # spuriously rank the empty set above every selection.
    profits = np.asarray(shift_scores(jnp.asarray(quality))[0])
    for frac in fractions:
        eps = EpsilonConstraint(fraction=float(frac), buckets=buckets)
        mask = np.asarray(select_under_budget(q, c, eps))[0]
        total_q = float(np.sum(np.where(mask, profits, 0.0)))
        total_c = float(np.sum(np.where(mask, costs, 0.0)) / max(np.sum(costs), 1e-9))
        out.append((total_c, total_q, mask))
    # keep non-dominated
    frontier = []
    best = -np.inf
    for tc, tq, m in sorted(out, key=lambda t: (t[0], -t[1])):
        if tq > best:
            frontier.append((tc, tq, m))
            best = tq
    return frontier
