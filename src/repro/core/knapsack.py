"""0/1 knapsack subroutine (paper Algorithm 1, Appendix A.1).

Three implementations:

* :func:`knapsack_reference` — the paper's Algorithm 1, verbatim Python.
  Ground truth for tests.
* :func:`knapsack_select` — batched, jittable backtrack-free bitmask DP
  used by the serving engine (one knapsack per query per batch).
* ``repro.kernels.knapsack`` — Pallas TPU kernel of the same bitmask
  formulation, with the DP row *and* mask row resident in VMEM (the
  selection hot-spot at serving batch sizes).

The bitmask formulation carries, next to each DP capacity entry
``dp[j]``, the packed item subset that achieves it (one ``uint32`` word
per 32 items).  The subset recurrence mirrors the value recurrence —
``mask'[j] = take ? mask[j-c] | (1 << i) : mask[j]`` — so the selection
pops out of the final row at ``j = budget`` with no ``[N, Q, B+1]``
take tensor and no second sequential backtrack loop.  ``take`` is the
*strict* improvement test, which reproduces Algorithm 1's
ties-keep-not-taken backtrack rule exactly.

Profit transformation (paper Eq. 4-5): BARTScores are negative, so profits
are ``alpha + score`` with ``alpha > max|score|``.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Score transformation (Eq. 4)
# ---------------------------------------------------------------------------


def shift_scores(scores: jax.Array | np.ndarray, alpha: float | None = None):
    """Target Score = alpha + BARTScore, alpha > max|BARTScore| (Eq. 4-5)."""
    s = jnp.asarray(scores, jnp.float32)
    if alpha is None:
        alpha = float(jnp.max(jnp.abs(s))) * 1.01 + 1e-6
    if alpha <= float(jnp.max(jnp.abs(s))):
        raise ValueError("alpha must exceed max|score| (paper Eq. 5)")
    return s + alpha, alpha


# ---------------------------------------------------------------------------
# Reference (paper Algorithm 1)
# ---------------------------------------------------------------------------


def knapsack_reference(models: Sequence[dict], budget: int) -> List[dict]:
    """Verbatim paper Algorithm 1. models: [{'cost': int, 'target_score': float}]."""
    n = len(models)
    dp = [[0.0] * (budget + 1) for _ in range(n + 1)]
    for i in range(1, n + 1):
        for j in range(budget + 1):
            if models[i - 1]["cost"] <= j:
                dp[i][j] = max(
                    dp[i - 1][j],
                    dp[i - 1][j - models[i - 1]["cost"]] + models[i - 1]["target_score"],
                )
            else:
                dp[i][j] = dp[i - 1][j]
    selected = []
    j = budget
    for i in range(n, 0, -1):
        if dp[i][j] != dp[i - 1][j]:
            selected.append(models[i - 1])
            j -= models[i - 1]["cost"]
    return selected


# ---------------------------------------------------------------------------
# Batched jittable bitmask DP (backtrack-free)
# ---------------------------------------------------------------------------


def mask_words(n: int) -> int:
    """uint32 words needed to hold one bit per item."""
    return max(1, -(-n // 32))


def unpack_selection(words: jax.Array, n: int) -> jax.Array:
    """[Q, W] uint32 packed subsets -> [Q, N] bool selection mask."""
    idx = jnp.arange(n, dtype=jnp.int32)
    bits = words[:, idx // 32] >> (idx % 32).astype(jnp.uint32)
    return (bits & jnp.uint32(1)).astype(bool)


def knapsack_select(profits: jax.Array, costs: jax.Array, budget: int) -> jax.Array:
    """Solve Q independent knapsacks.

    profits: [Q, N] float32, non-negative (already alpha-shifted).
    costs:   [Q, N] int32, >= 1 (bucketized — see cost.normalize_costs).
    budget:  static int capacity.
    Returns: [Q, N] bool selection mask, optimal per query.

    One forward pass; the selection rides along as per-capacity ``uint32``
    bitmasks (peak live state ``O(Q * (B+1))`` words), matching Algorithm
    1's backtrack — including its ties-keep-not-taken rule — bit for bit.
    """
    profits = jnp.asarray(profits, jnp.float32)
    costs = jnp.asarray(costs, jnp.int32)
    q, n = profits.shape
    bp1 = budget + 1
    w = mask_words(n)
    js = jnp.arange(bp1, dtype=jnp.int32)
    word_ids = jnp.arange(w, dtype=jnp.int32)

    def item_step(i, carry):
        dp, masks = carry  # dp [Q, B+1] f32; masks [Q, W, B+1] uint32
        c = costs[:, i][:, None]  # [Q,1]
        p = profits[:, i][:, None]
        idx = js[None, :] - c  # [Q, B+1]
        valid = idx >= 0
        safe = jnp.maximum(idx, 0)
        prev = jnp.take_along_axis(dp, safe, axis=1)
        cand = jnp.where(valid, prev + p, -jnp.inf)
        tk = cand > dp  # strict: ties keep "not taken" (Algorithm 1 backtrack)
        shifted = jnp.take_along_axis(
            masks, jnp.broadcast_to(safe[:, None, :], (q, w, bp1)), axis=2
        )
        bit = jnp.where(
            word_ids == i // 32,
            jax.lax.shift_left(jnp.uint32(1), (i % 32).astype(jnp.uint32)),
            jnp.uint32(0),
        )  # [W]
        new_masks = jnp.where(tk[:, None, :], shifted | bit[None, :, None], masks)
        return jnp.maximum(dp, cand), new_masks

    dp0 = jnp.zeros((q, bp1), jnp.float32)
    masks0 = jnp.zeros((q, w, bp1), jnp.uint32)
    _, masks = jax.lax.fori_loop(0, n, item_step, (dp0, masks0))
    return unpack_selection(masks[:, :, budget], n)


def knapsack_value(profits: jax.Array, costs: jax.Array, budget: int) -> jax.Array:
    """Optimal total profit per query (no backtrack) — used by tests."""
    sel = knapsack_select(profits, costs, budget)
    return jnp.sum(jnp.where(sel, profits, 0.0), axis=1)


# ---------------------------------------------------------------------------
# Exact bi-objective enumeration (tests / Pareto ground truth, N <= 20)
# ---------------------------------------------------------------------------


def enumerate_pareto(profits: np.ndarray, costs: np.ndarray) -> List[Tuple[float, float, int]]:
    """All non-dominated (cost, profit, subset_bitmask) points of one query."""
    n = len(profits)
    pts = []
    for mask in range(1, 1 << n):
        c = sum(costs[i] for i in range(n) if mask >> i & 1)
        p = sum(profits[i] for i in range(n) if mask >> i & 1)
        pts.append((c, p, mask))
    pts.sort(key=lambda t: (t[0], -t[1]))
    frontier = []
    best = -np.inf
    for c, p, m in pts:
        if p > best:
            frontier.append((c, p, m))
            best = p
    return frontier
