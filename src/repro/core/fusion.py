"""GEN-FUSER (Jiang et al. 2023) — fuses the selected models' responses.

The fuser is a Flan-T5-style enc-dec (``configs/gen_fuser.py``).  Its
encoder consumes ``query <sep> response_1 <sep> ... <sep> response_k`` and
the decoder emits the fused response.  This module builds the fusion input
from token arrays; greedy generation lives in ``repro.serve.generate``.
"""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def build_fusion_input(
    query: np.ndarray,  # [Sq] tokens
    responses: Sequence[np.ndarray],  # list of [Sr] token arrays (selected subset)
    sep_id: int,
    max_len: int,
    pad_id: int = 0,
) -> np.ndarray:
    """Concatenate query + responses with separators, pad/truncate to max_len."""
    parts: List[np.ndarray] = [np.asarray(query)]
    for r in responses:
        parts.append(np.asarray([sep_id]))
        parts.append(np.asarray(r))
    flat = np.concatenate(parts)[:max_len]
    out = np.full((max_len,), pad_id, np.int32)
    out[: len(flat)] = flat
    return out


def build_fusion_batch(
    queries: np.ndarray,  # [B, Sq]
    responses: np.ndarray,  # [B, N, Sr] all pool responses
    mask: np.ndarray,  # [B, N] selection
    sep_id: int,
    max_len: int,
    pad_id: int = 0,
) -> np.ndarray:
    """[B, max_len] fusion encoder inputs for a batch of selections."""
    b = queries.shape[0]
    out = np.zeros((b, max_len), np.int32)
    for i in range(b):
        sel = [responses[i, j] for j in range(mask.shape[1]) if mask[i, j]]
        q = queries[i][queries[i] != pad_id]
        sel = [r[r != pad_id] for r in sel]
        out[i] = build_fusion_input(q, sel, sep_id, max_len, pad_id)
    return out
