"""MODI quality predictor (paper §2.3, Appendix A.2).

DeBERTa-style encoder (He et al. 2021): disentangled attention with
content-to-content, content-to-position and position-to-content terms over
relative-position embeddings.  Regression head per Figure 1: the CLS hidden
state -> Dropout(0.2) -> GELU -> Linear -> GLU -> Linear(N) giving one
predicted quality score per pool member from the query alone.

Trained with Huber loss (delta = 0.3) and Adam(3e-4, betas=(0.9, 0.98),
weight decay 0.01) per Table 2.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_norm,
    dense_init,
    embed_init,
    huber_loss,
    init_embedding,
    init_mlp,
    apply_mlp,
    init_norm,
)

MAX_REL = 64  # relative-position bucket radius (2*MAX_REL embeddings)


@dataclasses.dataclass(frozen=True)
class PredictorConfig:
    encoder: ModelConfig
    num_models: int
    dropout: float = 0.2
    huber_delta: float = 0.3


class QualityPredictor:
    def __init__(self, cfg: PredictorConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.encoder.dtype)

    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        ecfg, dtype = self.cfg.encoder, self.dtype
        d, h, hd = ecfg.d_model, ecfg.num_heads, ecfg.resolved_head_dim
        ks = jax.random.split(key, 10)

        def enc_block(k):
            k1, k2, k3, k4, k5 = jax.random.split(k, 5)
            return {
                "norm1": init_norm(d, dtype, ecfg.norm),
                "wq": dense_init(k1, d, (d, h, hd), dtype),
                "wk": dense_init(k2, d, (d, h, hd), dtype),
                "wv": dense_init(k3, d, (d, h, hd), dtype),
                "wo": dense_init(k4, h * hd, (h, hd, d), dtype),
                # disentangled position projections (shared rel-pos table below)
                "wq_r": dense_init(k1, d, (d, h, hd), dtype),
                "wk_r": dense_init(k2, d, (d, h, hd), dtype),
                "norm2": init_norm(d, dtype, ecfg.norm),
                "mlp": init_mlp(k5, d, ecfg.d_ff, dtype),
            }

        n = self.cfg.num_models
        return {
            "embed": init_embedding(ks[0], ecfg.vocab_size, d, dtype),
            "rel_embed": embed_init(ks[1], (2 * MAX_REL, d), dtype),
            "blocks": jax.vmap(enc_block)(jax.random.split(ks[2], ecfg.num_layers)),
            "final_norm": init_norm(d, dtype, ecfg.norm),
            "head": {
                "lin1": dense_init(ks[3], d, (d, d), dtype),
                "b1": jnp.zeros((d,), dtype),
                "glu_w": dense_init(ks[4], d, (d, d), dtype),
                "glu_b": jnp.zeros((d,), dtype),
                "glu_v": dense_init(ks[5], d, (d, d), dtype),
                "glu_c": jnp.zeros((d,), dtype),
                "out": dense_init(ks[6], d, (d, n), dtype),
                "out_b": jnp.zeros((n,), dtype),
            },
        }

    # ------------------------------------------------------------------
    def _disentangled_attention(self, p_l, rel_embed, x):
        """DeBERTa attention: c2c + c2p + p2c with relative positions."""
        ecfg = self.cfg.encoder
        b, s, d = x.shape
        q = jnp.einsum("bsd,dhk->bshk", x, p_l["wq"])
        k = jnp.einsum("bsd,dhk->bshk", x, p_l["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p_l["wv"])
        # relative position deltas bucketized to [0, 2*MAX_REL)
        pos = jnp.arange(s)
        delta = jnp.clip(pos[:, None] - pos[None, :], -MAX_REL, MAX_REL - 1) + MAX_REL  # [S,S]
        kr = jnp.einsum("rd,dhk->rhk", rel_embed, p_l["wk_r"])  # [R,H,hd]
        qr = jnp.einsum("rd,dhk->rhk", rel_embed, p_l["wq_r"])
        f32 = jnp.float32
        c2c = jnp.einsum("bihk,bjhk->bhij", q.astype(f32), k.astype(f32))
        # c2p: q_c[i] . k_r[delta(i,j)]
        qkr = jnp.einsum("bihk,rhk->bhir", q.astype(f32), kr.astype(f32))  # [B,H,S,R]
        c2p = jnp.take_along_axis(qkr, delta[None, None, :, :], axis=-1)  # [B,H,S,S]
        # p2c: k_c[j] . q_r[delta(j,i)]
        kqr = jnp.einsum("bjhk,rhk->bhjr", k.astype(f32), qr.astype(f32))
        p2c = jnp.take_along_axis(kqr, delta.T[None, None, :, :], axis=-1)  # [B,H,S(j),S(i)]
        p2c = jnp.swapaxes(p2c, -1, -2)
        scale = 1.0 / jnp.sqrt(jnp.asarray(3 * q.shape[-1], f32))
        probs = jax.nn.softmax((c2c + c2p + p2c) * scale, axis=-1)
        out = jnp.einsum("bhij,bjhk->bihk", probs, v.astype(f32)).astype(x.dtype)
        return jnp.einsum("bshk,hkd->bsd", out, p_l["wo"])

    def encode(self, params: dict, tokens: jax.Array) -> jax.Array:
        """tokens: [B, S] -> hidden [B, S, D] (token 0 is CLS)."""
        ecfg = self.cfg.encoder
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        rel = params["rel_embed"]

        def body(xc, p_l):
            h = apply_norm(p_l["norm1"], xc, ecfg.norm_eps)
            xc = xc + self._disentangled_attention(p_l, rel, h)
            h2 = apply_norm(p_l["norm2"], xc, ecfg.norm_eps)
            return xc + apply_mlp(p_l["mlp"], h2, ecfg.act), None

        x, _ = jax.lax.scan(body, x, params["blocks"])
        return apply_norm(params["final_norm"], x, ecfg.norm_eps)

    # ------------------------------------------------------------------
    def apply(
        self,
        params: dict,
        tokens: jax.Array,
        train: bool = False,
        rng: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Predict r_hat(m_i, q) for every pool member: [B, num_models]."""
        h = self.encode(params, tokens)
        cls = h[:, 0, :]  # CLS pooling (A.2: best of the aggregations tried)
        hd = params["head"]
        x = cls
        if train:
            keep = 1.0 - self.cfg.dropout
            mask = jax.random.bernoulli(rng, keep, x.shape)
            x = jnp.where(mask, x / keep, 0.0)
        x = jax.nn.gelu(x)  # GELU (Eq. 6)
        x = x @ hd["lin1"] + hd["b1"]
        x = (x @ hd["glu_w"] + hd["glu_b"]) * jax.nn.sigmoid(x @ hd["glu_v"] + hd["glu_c"])  # Eq. 7
        return x @ hd["out"] + hd["out_b"]

    def loss(self, params, batch, rng=None) -> Tuple[jax.Array, dict]:
        """batch: {tokens [B,S], scores [B,N]} -> Huber(delta=0.3) (Eq. 8)."""
        train = rng is not None
        pred = self.apply(params, batch["tokens"], train=train, rng=rng)
        l = huber_loss(pred, batch["scores"], self.cfg.huber_delta)
        mae = jnp.mean(jnp.abs(pred - batch["scores"]))
        return l, {"loss": l, "mae": mae}


def build_predictor(num_models: int, encoder: Optional[ModelConfig] = None) -> QualityPredictor:
    if encoder is None:
        from repro import configs

        encoder = configs.get("modi-predictor")
    return QualityPredictor(PredictorConfig(encoder=encoder, num_models=num_models))
