from repro.core.cost import CostModel, cost_model_from_config, normalize_costs, pool_costs
from repro.core.epsilon import EpsilonConstraint, pareto_sweep, select_under_budget
from repro.core.knapsack import (
    enumerate_pareto,
    knapsack_reference,
    knapsack_select,
    knapsack_value,
    shift_scores,
)
from repro.core.metrics import bartscore, token_f1
from repro.core.predictor import PredictorConfig, QualityPredictor, build_predictor
from repro.core.selector import (
    BestSinglePolicy,
    FixedSinglePolicy,
    FullEnsemblePolicy,
    GreedyRatioPolicy,
    HybridRouterPolicy,
    ModiPolicy,
    PolicyRegistry,
    RandomPolicy,
    SelectionPolicy,
    available_policies,
    make_policy,
    realized_cost_fraction,
)

__all__ = [
    "CostModel", "cost_model_from_config", "normalize_costs", "pool_costs",
    "EpsilonConstraint", "pareto_sweep", "select_under_budget",
    "enumerate_pareto", "knapsack_reference", "knapsack_select", "knapsack_value",
    "shift_scores", "bartscore", "token_f1",
    "PredictorConfig", "QualityPredictor", "build_predictor",
    "BestSinglePolicy", "FixedSinglePolicy", "FullEnsemblePolicy",
    "GreedyRatioPolicy", "HybridRouterPolicy", "ModiPolicy", "RandomPolicy",
    "SelectionPolicy", "realized_cost_fraction",
    "PolicyRegistry", "make_policy", "available_policies",
]
