"""Quality metrics — native BARTScore (paper §3, A.4).

BARTScore(candidate -> reference) is the mean conditional log-likelihood of
the reference under a seq2seq LM given the candidate:

    score = (1/|y|) Σ_t log p(y_t | y_<t, x)

The paper scores with BART-large; the math is model-agnostic, so we compute
it under the in-framework ``bartscore-scorer`` enc-dec (DESIGN.md §3).
Scores are negative; higher is better.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.encdec import EncDecLM


def bartscore(
    scorer: EncDecLM,
    params: dict,
    cand_tokens: jax.Array,  # [B, Sc] candidate (conditions the encoder)
    ref_tokens: jax.Array,  # [B, Sr] reference (scored by the decoder)
    ref_mask: Optional[jax.Array] = None,  # [B, Sr] 1 = real token
) -> jax.Array:
    """Per-example BARTScore [B]."""
    logits = scorer.forward(params, ref_tokens, enc_tokens=cand_tokens)
    logprobs = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tgt = ref_tokens[:, 1:]
    lp = jnp.take_along_axis(logprobs[:, :-1], tgt[..., None], axis=-1)[..., 0]  # [B, Sr-1]
    if ref_mask is None:
        mask = jnp.ones_like(lp)
    else:
        mask = ref_mask[:, 1:].astype(jnp.float32)
    return jnp.sum(lp * mask, axis=1) / jnp.maximum(jnp.sum(mask, axis=1), 1.0)


def token_f1(pred: jax.Array, ref: jax.Array, pad_id: int = 0) -> jax.Array:
    """Bag-of-token F1 between two token sequences [B, S] (synthetic-task aid)."""
    def counts(x):
        v = jnp.arange(512)
        return jnp.sum((x[:, :, None] == v[None, None, :]) & (x[:, :, None] != pad_id), axis=1)

    cp, cr = counts(pred), counts(ref)
    overlap = jnp.sum(jnp.minimum(cp, cr), axis=-1).astype(jnp.float32)
    p = overlap / jnp.maximum(jnp.sum(cp, -1), 1)
    r = overlap / jnp.maximum(jnp.sum(cr, -1), 1)
    return jnp.where(p + r > 0, 2 * p * r / jnp.maximum(p + r, 1e-9), 0.0)
