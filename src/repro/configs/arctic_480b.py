"""arctic-480b — dense-MoE hybrid: 128-expert top-2 MoE in parallel with a
dense residual MLP every layer.

35L d_model=7168 56H (GQA kv=8) d_ff=4864(per-expert) vocab=32000
[hf:Snowflake/snowflake-arctic-base]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,  # dense residual branch hidden size
    vocab_size=32000,
    head_dim=128,
    num_experts=128,
    moe_top_k=2,
    moe_d_ff=4864,
    dense_residual=True,
    capacity_factor=1.25,
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="silu",
    dtype="bfloat16",
    source="hf:Snowflake/snowflake-arctic-base",
)
