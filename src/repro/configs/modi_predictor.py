"""MODI quality predictor — DeBERTa-style disentangled-attention encoder.

The paper uses DeBERTa-v3-large as the backbone; we train a same-shape-family
encoder from scratch at laptop scale (the head is the faithful part:
CLS -> dropout(0.2) -> GELU -> Linear -> GLU -> Linear(N), Huber delta=0.3,
Adam lr 3e-4 betas (0.9, 0.98) weight decay 0.01 — paper Table 2 / A.2).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="modi-predictor",
    family="encoder",
    num_layers=4,
    d_model=256,
    num_heads=8,
    num_kv_heads=8,
    d_ff=1024,
    vocab_size=512,  # byte-level tokenizer + specials
    head_dim=32,
    norm="layernorm",
    act="gelu",
    dtype="float32",
    source="paper A.2 (He et al. 2021 DeBERTa backbone)",
)
