"""qwen2.5-32b — dense GQA decoder with QKV bias.

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064
[hf:Qwen/Qwen2.5-0.5B family scaled per assignment]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    norm_eps=1e-6,
    act="silu",
    dtype="bfloat16",
    source="hf:Qwen/Qwen2.5-0.5B",
)
