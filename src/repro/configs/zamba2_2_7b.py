"""zamba2-2.7b — hybrid: Mamba2 backbone + weight-tied shared attention.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000 ssm_state=64
[arXiv:2411.15242]

The shared GQA block is applied after every 6 Mamba2 layers with tied
weights (Zamba2's shared-attention design).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="silu",
    dtype="bfloat16",
    source="arXiv:2411.15242",
)
