"""BARTScore scorer — enc-dec LM whose conditional log-likelihood defines
the quality metric (BARTScore = mean log p(reference | candidate)).

The paper scores with BART-large; the metric's math is model-agnostic, so we
train a small enc-dec scorer in-framework and report BARTScore under it
(orderings, not absolute values, are the reproduction target — DESIGN.md §3).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="bartscore-scorer",
    family="audio",  # enc-dec plumbing with text-token encoder input
    num_layers=3,
    d_model=192,
    num_heads=6,
    num_kv_heads=6,
    d_ff=768,
    vocab_size=512,
    head_dim=32,
    is_encoder_decoder=True,
    enc_layers=3,
    enc_seq=512,
    norm="layernorm",
    act="gelu",
    dtype="float32",
    tie_embeddings=True,
    source="Yang & Yang 2023 / Yuan et al. BARTScore definition",
)
