"""mamba2-370m — attention-free SSD (state-space duality) stack.

48L d_model=1024 d_ff=0 vocab=50280 ssm_state=128 [arXiv:2405.21060]
d_inner = 2*d_model = 2048, head_dim 64 -> 32 SSD heads.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    norm="rmsnorm",
    dtype="bfloat16",
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
