"""command-r-plus-104b — dense GQA, no-bias, parallel attention/MLP block.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01 family]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    head_dim=128,
    qkv_bias=False,
    parallel_block=True,
    rope_theta=75_000_000.0,
    norm="layernorm",
    act="silu",
    dtype="bfloat16",
    tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
