"""smollm-360m — llama-architecture small dense decoder.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M family]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    head_dim=64,
    rope_theta=10_000.0,
    norm="rmsnorm",
    norm_eps=1e-5,
    act="silu",
    dtype="bfloat16",
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
