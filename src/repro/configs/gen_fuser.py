"""GEN-FUSER — Flan-T5-style encoder-decoder fusion model (Jiang et al. 2023).

The paper uses the open-sourced Flan-T5-XL GEN-FUSER; we train a
same-family enc-dec from scratch at laptop scale.  Encoder input: query +
candidate responses (concatenated, separator-delimited); decoder output:
the fused response.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gen-fuser",
    family="audio",  # enc-dec plumbing; text tokens are fed to the encoder
    num_layers=4,
    d_model=256,
    num_heads=8,
    num_kv_heads=8,
    d_ff=1024,
    vocab_size=512,
    head_dim=32,
    is_encoder_decoder=True,
    enc_layers=4,
    enc_seq=1024,
    norm="rmsnorm",
    act="gelu",
    dtype="float32",
    tie_embeddings=True,
    source="Jiang et al. 2023 (LLM-BLENDER GEN-FUSER, Flan-T5 family)",
)
