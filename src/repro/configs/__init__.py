"""Architecture config registry.

Every assigned architecture has one module here exporting ``CONFIG``; the
paper's own models (MODI quality predictor, GEN-FUSER, BARTScore scorer,
ensemble pool members) are configs too.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ASSIGNED_ARCHS: List[str] = [
    "qwen2.5-32b",
    "internvl2-1b",
    "zamba2-2.7b",
    "minicpm3-4b",
    "command-r-plus-104b",
    "deepseek-v3-671b",
    "mamba2-370m",
    "smollm-360m",
    "whisper-base",
    "arctic-480b",
]

EXTRA_ARCHS: List[str] = [
    "modi-predictor",
    "gen-fuser",
    "bartscore-scorer",
]

_MODULE_FOR: Dict[str, str] = {
    "qwen2.5-32b": "qwen2_5_32b",
    "internvl2-1b": "internvl2_1b",
    "zamba2-2.7b": "zamba2_2_7b",
    "minicpm3-4b": "minicpm3_4b",
    "command-r-plus-104b": "command_r_plus_104b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "mamba2-370m": "mamba2_370m",
    "smollm-360m": "smollm_360m",
    "whisper-base": "whisper_base",
    "arctic-480b": "arctic_480b",
    "modi-predictor": "modi_predictor",
    "gen-fuser": "gen_fuser",
    "bartscore-scorer": "bartscore_scorer",
}


def get(arch: str) -> ModelConfig:
    if arch not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch]}")
    return mod.CONFIG


def all_assigned() -> Dict[str, ModelConfig]:
    return {a: get(a) for a in ASSIGNED_ARCHS}
