"""whisper-base — encoder-decoder audio backbone; conv/mel frontend stubbed.

6L (enc) + 6L (dec) d_model=512 8H d_ff=2048 vocab=51865 [arXiv:2212.04356]

Per spec the mel-spectrogram + conv feature extractor is a STUB:
``input_specs`` supplies 1500 precomputed frame embeddings (the output
length of Whisper's conv frontend for 30s audio) of dim 512.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    is_encoder_decoder=True,
    enc_layers=6,
    enc_seq=1500,
    frontend_tokens=1500,
    frontend_dim=512,
    norm="layernorm",
    act="gelu",
    dtype="bfloat16",
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
