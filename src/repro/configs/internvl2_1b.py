"""internvl2-1b — VLM: InternViT frontend (stub) + Qwen2-0.5B-style LM.

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655 [arXiv:2404.16821]

Per spec the ViT/projector frontend is a STUB: ``input_specs`` feeds
precomputed patch embeddings (256 tokens of dim 1024, the InternViT-300M
projector output length for a 448px tile) which the LM consumes through a
learned projection.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    norm_eps=1e-6,
    act="silu",
    dtype="bfloat16",
    frontend_tokens=256,
    frontend_dim=1024,
    tie_embeddings=True,
    source="arXiv:2404.16821",
)
