"""deepseek-v3-671b — MoE decoder with MLA, shared expert, and MTP.

61L d_model=7168 128H d_ff=2048(per-expert) vocab=129280, MoE 256 experts
top-8 + 1 shared, 3 leading dense layers, depth-1 multi-token prediction.
[arXiv:2412.19437]

MLA dims per the DeepSeek-V3 report: q_lora=1536, kv_lora=512, qk_nope=128,
qk_rope=64, v_head=128.  Dense layers and the shared expert use the model's
dense FFN width 18432.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,  # dense-layer / shared-expert hidden size
    vocab_size=129280,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    num_experts=256,
    num_shared_experts=1,
    moe_top_k=8,
    moe_d_ff=2048,
    first_dense_layers=3,
    capacity_factor=1.25,
    mtp=True,
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="silu",
    dtype="bfloat16",
    source="arXiv:2412.19437",
)
