"""minicpm3-4b — dense decoder with Multi-head Latent Attention (MLA).

62L d_model=2560 40H d_ff=6400 vocab=73448 [hf:openbmb/MiniCPM3-4B]

MLA dims follow the MiniCPM3 model card: q_lora_rank=768, kv_lora_rank=256,
qk_nope=64, qk_rope=32, v_head=64 (the paper-assigned "GQA kv=40" is the
head count; MLA caches the 256-d latent, not per-head KV).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    use_mla=True,
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="silu",
    dtype="bfloat16",
    tie_embeddings=True,
    source="hf:openbmb/MiniCPM3-4B",
)
