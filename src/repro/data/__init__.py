from repro.data.batching import fuser_batches, lm_batches, predictor_batches, scorer_batches
from repro.data.mixinstruct import (
    DEFAULT_POOL,
    DOMAIN_NAMES,
    DOMAINS,
    POOL_NAMES,
    PoolMemberSpec,
    Record,
    expected_tokens,
    generate_dataset,
    member_response,
    pool_responses,
    query_cost_matrix,
)
from repro.data.tokenizer import TOKENIZER, ByteTokenizer

__all__ = [
    "fuser_batches", "lm_batches", "predictor_batches", "scorer_batches",
    "DEFAULT_POOL", "DOMAIN_NAMES", "DOMAINS", "POOL_NAMES",
    "PoolMemberSpec", "Record", "expected_tokens", "generate_dataset",
    "member_response", "pool_responses", "query_cost_matrix",
    "TOKENIZER", "ByteTokenizer",
]
