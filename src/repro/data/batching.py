"""Batch builders for every trainable component, plus a generic LM pipeline."""

from __future__ import annotations

from typing import Dict, Iterator, List, Sequence

import numpy as np

from repro.data.mixinstruct import PoolMemberSpec, Record, member_response
from repro.data.tokenizer import TOKENIZER


def lm_batches(
    records: Sequence[Record],
    batch_size: int,
    max_len: int,
    seed: int = 0,
    member: PoolMemberSpec | None = None,
) -> Iterator[Dict[str, np.ndarray]]:
    """Decoder-LM batches: ``query <sep> response <eos>`` with loss on the
    response.  With ``member`` given, responses follow that member's
    competence profile (used to train live pool models)."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(records))
    tok = TOKENIZER
    for start in range(0, len(order) - batch_size + 1, batch_size):
        seqs, masks = [], []
        for idx in order[start : start + batch_size]:
            rec = records[idx]
            resp = rec.reference if member is None else member_response(member, rec, rng)
            q = tok.encode(rec.query, bos=True)
            r = tok.encode(resp, eos=True)
            seq = q + [tok.sep_id] + r
            mask = [0] * (len(q) + 1) + [1] * len(r)
            seqs.append(seq[:max_len])
            masks.append(mask[:max_len])
        tokens = tok.pad_batch(seqs, max_len)
        loss_mask = np.zeros_like(tokens, np.float32)
        for i, m in enumerate(masks):
            loss_mask[i, : len(m)] = m
        yield {"tokens": tokens, "loss_mask": loss_mask}


def scorer_batches(
    records: Sequence[Record],
    pool: Sequence[PoolMemberSpec],
    batch_size: int,
    max_enc: int,
    max_dec: int,
    seed: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    """BARTScore-scorer batches: encoder sees a candidate response ONLY
    (BARTScore's p(reference | candidate) — including the query would let
    the scorer shortcut through it on rule-derived references and stop
    conditioning on candidates at all), decoder is teacher-forced on the
    reference.  Candidates mix member outputs and clean references so
    log-likelihood tracks quality."""
    rng = np.random.default_rng(seed)
    tok = TOKENIZER
    order = rng.permutation(len(records))
    for start in range(0, len(order) - batch_size + 1, batch_size):
        enc, dec, masks = [], [], []
        for idx in order[start : start + batch_size]:
            rec = records[idx]
            if rng.uniform() < 0.25:
                cand = rec.reference
            else:
                cand = member_response(pool[int(rng.integers(0, len(pool)))], rec, rng)
            enc.append(tok.encode(cand))
            d = tok.encode(rec.reference, bos=True, eos=True)
            dec.append(d)
            masks.append([1] * len(d))
        enc_tokens = tok.pad_batch(enc, max_enc)
        dec_tokens = tok.pad_batch(dec, max_dec)
        loss_mask = np.zeros_like(dec_tokens, np.float32)
        for i, m in enumerate(masks):
            loss_mask[i, : min(len(m), max_dec)] = m[:max_dec]
        yield {"enc_tokens": enc_tokens, "dec_tokens": dec_tokens, "loss_mask": loss_mask}


def fuser_batches(
    records: Sequence[Record],
    pool: Sequence[PoolMemberSpec],
    batch_size: int,
    max_enc: int,
    max_dec: int,
    seed: int = 0,
    subset_size: int = 3,
) -> Iterator[Dict[str, np.ndarray]]:
    """GEN-FUSER batches: encoder sees query + a random subset's responses,
    decoder is teacher-forced on the reference (fusion target)."""
    rng = np.random.default_rng(seed)
    tok = TOKENIZER
    order = rng.permutation(len(records))
    for start in range(0, len(order) - batch_size + 1, batch_size):
        enc, dec, masks = [], [], []
        for idx in order[start : start + batch_size]:
            rec = records[idx]
            members = rng.choice(len(pool), size=subset_size, replace=False)
            seq = tok.encode(rec.query)
            for mi in members:
                seq += [tok.sep_id] + tok.encode(member_response(pool[mi], rec, rng))
            enc.append(seq)
            d = tok.encode(rec.reference, bos=True, eos=True)
            dec.append(d)
            masks.append([1] * len(d))
        enc_tokens = tok.pad_batch(enc, max_enc)
        dec_tokens = tok.pad_batch(dec, max_dec)
        loss_mask = np.zeros_like(dec_tokens, np.float32)
        for i, m in enumerate(masks):
            loss_mask[i, : min(len(m), max_dec)] = m[:max_dec]
        yield {"enc_tokens": enc_tokens, "dec_tokens": dec_tokens, "loss_mask": loss_mask}


def predictor_batches(
    records: Sequence[Record],
    scores: np.ndarray,  # [Q, N] quality labels (BARTScore of each member)
    batch_size: int,
    max_len: int,
    seed: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    """MODI predictor batches: CLS + query tokens -> per-member scores."""
    rng = np.random.default_rng(seed)
    tok = TOKENIZER
    order = rng.permutation(len(records))
    for start in range(0, len(order) - batch_size + 1, batch_size):
        idxs = order[start : start + batch_size]
        tokens = tok.batch_encode([records[i].query for i in idxs], max_len, cls=True)
        yield {"tokens": tokens, "scores": scores[idxs].astype(np.float32)}
