"""Synthetic MixInstruct-style benchmark (offline stand-in for Jiang et al.
2023's 110K-instruction dataset — DESIGN.md §3, §7).

Eight instruction *domains* with rule-computable references, and a pool of
eight members mirroring the paper's LLM selection set (Table 2).  Each
member has a per-domain competence profile, chosen so that **no member
dominates** (the paper's premise), and a realistic Kaplan cost derived from
the real model's published size.

Two response paths:
* *behavioral simulation* (fast, controllable): the member emits the
  reference corrupted at a rate set by its competence — used by the
  Table-1 benchmark and unit tests;
* *live models*: tiny in-framework LMs trained per-member on
  competence-weighted data — used by the end-to-end example.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.core.cost import CostModel

# ---------------------------------------------------------------------------
# Instruction domains
# ---------------------------------------------------------------------------

_WORDS = (
    "apple river stone cloud tiger maple ember quartz violet breeze "
    "copper meadow falcon harbor indigo jasmine kernel lantern marble nectar"
).split()


def _d_echo(rng):
    w = " ".join(rng.choice(_WORDS, rng.integers(2, 5)))
    return f"Repeat exactly: {w}", w


def _d_upper(rng):
    w = " ".join(rng.choice(_WORDS, rng.integers(2, 4)))
    return f"Uppercase this text: {w}", w.upper()


def _d_reverse(rng):
    w = str(rng.choice(_WORDS))
    return f"Reverse the word: {w}", w[::-1]


def _d_sort(rng):
    digits = "".join(map(str, rng.integers(0, 10, rng.integers(4, 8))))
    return f"Sort the digits ascending: {digits}", "".join(sorted(digits))


def _d_add(rng):
    a, b = int(rng.integers(10, 99)), int(rng.integers(10, 99))
    return f"What is {a} plus {b}?", str(a + b)


def _d_max(rng):
    xs = rng.integers(10, 99, 3)
    return f"Which is largest: {xs[0]}, {xs[1]} or {xs[2]}?", str(int(xs.max()))


def _d_vowels(rng):
    w = str(rng.choice(_WORDS))
    return f"How many vowels are in '{w}'?", str(sum(c in "aeiou" for c in w))


def _d_initials(rng):
    ws = rng.choice(_WORDS, rng.integers(2, 5))
    return "First letter of each word: " + " ".join(ws), "".join(w[0] for w in ws)


DOMAINS: Dict[str, Callable] = {
    "echo": _d_echo,
    "upper": _d_upper,
    "reverse": _d_reverse,
    "sort": _d_sort,
    "add": _d_add,
    "max": _d_max,
    "vowels": _d_vowels,
    "initials": _d_initials,
}
DOMAIN_NAMES = list(DOMAINS)


@dataclasses.dataclass(frozen=True)
class Record:
    query: str
    reference: str
    domain: str
    domain_id: int


def generate_dataset(n: int, seed: int = 0) -> List[Record]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        di = int(rng.integers(0, len(DOMAIN_NAMES)))
        name = DOMAIN_NAMES[di]
        q, ref = DOMAINS[name](rng)
        out.append(Record(q, ref, name, di))
    return out


# ---------------------------------------------------------------------------
# Pool members (paper Table 2's eight LLMs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PoolMemberSpec:
    name: str
    params_b: float  # real model size (non-embedding, approx) for Kaplan cost
    n_layer: int
    d_model: int
    competence: Tuple[float, ...]  # per-domain success probability

    def cost_model(self) -> CostModel:
        return CostModel(
            name=self.name,
            params_active=int(self.params_b * 1e9),
            n_layer=self.n_layer,
            d_model=self.d_model,
        )


# Competence rows over (echo, upper, reverse, sort, add, max, vowels, initials).
# Diverse peaks: every member is best-in-pool somewhere; none dominates.
DEFAULT_POOL: List[PoolMemberSpec] = [
    PoolMemberSpec("alpaca-native", 6.7, 32, 4096, (0.95, 0.85, 0.30, 0.40, 0.55, 0.70, 0.35, 0.55)),
    PoolMemberSpec("vicuna-13b-1.1", 13.0, 40, 5120, (0.90, 0.90, 0.45, 0.60, 0.80, 0.85, 0.50, 0.65)),
    PoolMemberSpec("dolly-v2-12b", 11.3, 36, 5120, (0.70, 0.60, 0.25, 0.90, 0.45, 0.55, 0.30, 0.40)),
    PoolMemberSpec("stablelm-tuned-7b", 6.6, 16, 6144, (0.55, 0.45, 0.20, 0.30, 0.35, 0.45, 0.85, 0.30)),
    PoolMemberSpec("oasst-pythia-12b", 11.3, 36, 5120, (0.85, 0.75, 0.90, 0.50, 0.60, 0.70, 0.45, 0.60)),
    PoolMemberSpec("koala-7B", 6.7, 32, 4096, (0.80, 0.70, 0.35, 0.45, 0.90, 0.75, 0.40, 0.50)),
    PoolMemberSpec("flan-t5-xxl", 11.0, 24, 4096, (0.60, 0.80, 0.40, 0.55, 0.70, 0.80, 0.55, 0.90)),
    PoolMemberSpec("mpt-7b-instruct", 6.6, 32, 4096, (0.75, 0.65, 0.55, 0.50, 0.50, 0.60, 0.60, 0.70)),
]

POOL_NAMES = [m.name for m in DEFAULT_POOL]


# ---------------------------------------------------------------------------
# Behavioral response simulation
# ---------------------------------------------------------------------------

_GARBLE = "xqzjvkw"


def member_response(spec: PoolMemberSpec, rec: Record, rng: np.random.Generator) -> str:
    """Simulated response: correct with prob = competence; otherwise degraded
    (char corruption / truncation / off-task answer)."""
    comp = spec.competence[rec.domain_id]
    if rng.uniform() < comp:
        # correct, with light surface noise so members' phrasings differ
        resp = rec.reference
        if rng.uniform() < 0.15:
            resp = resp + "."
        return resp
    mode = rng.integers(0, 3)
    if mode == 0:  # corrupt characters
        chars = list(rec.reference)
        k = max(1, int(len(chars) * rng.uniform(0.3, 0.8)))
        for i in rng.choice(len(chars), size=min(k, len(chars)), replace=False):
            chars[i] = _GARBLE[int(rng.integers(0, len(_GARBLE)))]
        return "".join(chars)
    if mode == 1:  # truncate
        cut = max(1, len(rec.reference) // 2)
        return rec.reference[:cut]
    # off-task: answer a different random domain's style
    other = DOMAINS[DOMAIN_NAMES[int(rng.integers(0, len(DOMAIN_NAMES)))]]
    return other(rng)[1]


def pool_responses(
    pool: Sequence[PoolMemberSpec], records: Sequence[Record], seed: int = 0
) -> List[List[str]]:
    """responses[i][j] = member j's response to record i."""
    rng = np.random.default_rng(seed)
    return [[member_response(m, r, rng) for m in pool] for r in records]


def expected_tokens(spec: PoolMemberSpec, rec: Record) -> float:
    """t_i(q): expected generated token count (bytes) for this member.

    Weak members ramble less predictably; we use reference length plus a
    small member-dependent overhead — matching the paper's per-model t_i."""
    base = len(rec.reference) + 2
    overhead = 1.0 + 0.1 * (1.0 - float(np.mean(spec.competence)))
    return base * overhead


def query_cost_matrix(
    pool: Sequence[PoolMemberSpec], records: Sequence[Record]
) -> np.ndarray:
    """[Q, N] FLOPs: c_i * t_i(q) (paper Eq. 1)."""
    out = np.zeros((len(records), len(pool)))
    for qi, rec in enumerate(records):
        n_ctx = len(rec.query) + 8
        for mi, spec in enumerate(pool):
            cm = spec.cost_model()
            out[qi, mi] = cm.query_cost(n_ctx, expected_tokens(spec, rec))
    return out
