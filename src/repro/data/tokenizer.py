"""Byte-level tokenizer with special tokens (offline, deterministic).

ids 0..255 = raw bytes; specials follow.  Vocab 512 leaves headroom that the
small paper-core models (predictor / fuser / scorer) share.
"""

from __future__ import annotations

from typing import Iterable, List

import numpy as np

PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
SEP_ID = 259
CLS_ID = 260
VOCAB_SIZE = 512


class ByteTokenizer:
    pad_id = PAD_ID
    bos_id = BOS_ID
    eos_id = EOS_ID
    sep_id = SEP_ID
    cls_id = CLS_ID
    vocab_size = VOCAB_SIZE

    def encode(self, text: str, bos: bool = False, eos: bool = False) -> List[int]:
        ids = list(text.encode("utf-8", errors="replace"))
        if bos:
            ids = [BOS_ID] + ids
        if eos:
            ids = ids + [EOS_ID]
        return ids

    def decode(self, ids: Iterable[int]) -> str:
        raw = bytes(i for i in ids if 0 <= i < 256)
        return raw.decode("utf-8", errors="replace")

    def decode_capped(self, ids: Iterable[int], cap: int) -> str:
        """Decode at most ``cap`` tokens, stripping a trailing *incomplete*
        UTF-8 sequence the cut would otherwise turn into U+FFFD — a
        replacement char re-encodes to 3 bytes, so naive truncate-and-decode
        can yield text whose re-encoding exceeds the cap (up to 3x)."""
        raw = bytes(i for i in ids if 0 <= i < 256)[:max(cap, 0)]
        for k in range(1, min(4, len(raw)) + 1):
            b = raw[-k]
            if b < 0x80:  # ASCII tail — complete
                break
            if b >= 0xC0:  # lead byte k bytes from the end; sequence length:
                need = 2 if b < 0xE0 else 3 if b < 0xF0 else 4
                if need > k:  # cut mid-sequence -> drop the partial char
                    raw = raw[:-k]
                break
        return raw.decode("utf-8", errors="replace")

    def pad_batch(self, seqs: List[List[int]], max_len: int) -> np.ndarray:
        out = np.full((len(seqs), max_len), PAD_ID, np.int32)
        for i, s in enumerate(seqs):
            s = s[:max_len]
            out[i, : len(s)] = s
        return out

    def batch_encode(self, texts: List[str], max_len: int, cls: bool = False) -> np.ndarray:
        seqs = [([CLS_ID] if cls else []) + self.encode(t) for t in texts]
        return self.pad_batch(seqs, max_len)


TOKENIZER = ByteTokenizer()
