from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ops import decode_attend_cache

__all__ = ["decode_attention", "decode_attend_cache"]
