"""Pure-jnp oracle: single-token GQA decode attention over a (ring-buffer)
KV cache with per-slot absolute positions.

q     [B, KV, G, hd]   one new token, grouped heads
k, v  [B, KV, S, hd]   cache slots
pos   [B, S]           absolute position stored in each slot (-1 = empty)
cur   [B]              current query position
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array, cur: jax.Array, window: int = 0
) -> jax.Array:
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bkgh,bksh->bkgs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    ok = (pos >= 0) & (pos <= cur[:, None])
    if window > 0:
        ok &= pos > (cur[:, None] - window)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bksh->bkgh", p, v.astype(jnp.float32)).astype(q.dtype)
