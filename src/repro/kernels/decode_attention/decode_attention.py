"""Pallas TPU kernel: flash-decoding-style single-token GQA attention.

One query token attends over a long KV cache (up to 512k slots for the
``long_500k`` shape).  Grid (B, KV, num_k_blocks): the cache streams through
VMEM in ``block_k`` tiles along the innermost sequential axis while the
grouped query heads' online-softmax state (acc/m/l — tiny: [G, hd]) sits in
VMEM scratch.  Masking is *position-based* (each slot carries its absolute
position; -1 = empty), which makes the kernel agnostic to ring-buffer slot
order — exactly the cache semantics of ``repro.models.attention``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, pos_ref, cur_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, window: int, num_k_blocks: int,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # [G, hd]
    k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    slot_pos = pos_ref[0]  # [bk]
    cur = cur_ref[0, 0]  # scalar

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [G, bk]
    ok = (slot_pos >= 0) & (slot_pos <= cur)
    if window > 0:
        ok &= slot_pos > cur - window
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnums=(5, 6, 7))
def decode_attention(
    q: jax.Array,  # [B, KV, G, hd]
    k: jax.Array,  # [B, KV, S, hd]
    v: jax.Array,
    pos: jax.Array,  # [B, S] int32 slot positions (-1 empty)
    cur: jax.Array,  # [B] int32 current position
    window: int = 0,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, kv, g, hd = q.shape
    s = k.shape[2]
    block_k = min(block_k, s)
    pad = (-s) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        pos = jnp.pad(pos, ((0, 0), (0, pad)), constant_values=-1)
    sp = k.shape[2]
    nk = sp // block_k
    scale = 1.0 / (hd ** 0.5)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window, num_k_blocks=nk),
        grid=(b, kv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bb, kk, ki: (bb, kk, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bb, kk, ki: (bb, kk, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bb, kk, ki: (bb, kk, ki, 0)),
            pl.BlockSpec((1, block_k), lambda bb, kk, ki: (bb, ki)),
            pl.BlockSpec((1, 1), lambda bb, kk, ki: (bb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bb, kk, ki: (bb, kk, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, pos.astype(jnp.int32), cur.astype(jnp.int32)[:, None])
    return out
