"""Jitted wrapper matching the model cache layout [B, S, KV, hd]."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attend_cache(
    q_bshd: jax.Array,  # [B, 1, H, hd] — model layout single step
    cache_k: jax.Array,  # [B, S, KV, hd]
    cache_v: jax.Array,
    cache_pos: jax.Array,  # [B, S]
    cur: jax.Array,  # [B]
    window: int = 0,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Returns attention output in model layout [B, 1, H, hd]."""
    b, _, h, hd = q_bshd.shape
    kv = cache_k.shape[2]
    g = h // kv
    q = q_bshd[:, 0].reshape(b, kv, g, hd)
    k = cache_k.swapaxes(1, 2)  # [B, KV, S, hd]
    v = cache_v.swapaxes(1, 2)
    if use_pallas:
        out = decode_attention(q, k, v, cache_pos, cur, window, interpret=interpret)
    else:
        out = decode_attention_ref(q, k, v, cache_pos, cur, window)
    return out.reshape(b, 1, h, hd)
