from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ssd_scan import ssd_scan

__all__ = ["ssd", "ssd_scan"]
