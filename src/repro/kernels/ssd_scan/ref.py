"""Oracle for the SSD scan kernel — re-exports the model-level pure-jnp
implementations (chunked + naive sequential)."""

from repro.models.ssm import ssd_chunked, ssd_reference

__all__ = ["ssd_chunked", "ssd_reference"]
