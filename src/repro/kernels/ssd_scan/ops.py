"""Jitted public API for the SSD scan kernel with pure-JAX fallback."""

from __future__ import annotations

import jax

from repro.kernels.ssd_scan.ref import ssd_chunked, ssd_reference
from repro.kernels.ssd_scan.ssd_scan import ssd_scan


def ssd(x, dt, a, bm, cm, chunk: int = 128, use_pallas: bool = True, interpret: bool = True):
    """(y, h_final) via the Pallas kernel or the pure-JAX chunked path."""
    if use_pallas:
        return ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=interpret)
    return ssd_chunked(x, dt, a, bm, cm, chunk=chunk)
