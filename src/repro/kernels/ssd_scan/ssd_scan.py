"""Pallas TPU kernel: Mamba2 SSD chunked scan (arXiv:2405.21060).

Grid (B, NH, num_chunks); chunks are the innermost sequential axis with the
recurrent state ``h`` [hd, N] carried in VMEM scratch.  Per chunk the kernel
computes the intra-chunk quadratic term (an L×L "attention" on the MXU),
the inbound-state contribution, and the chunk-final state update — the
TPU-native realization of the SSD duality: quadratic inside the chunk,
linear recurrence across chunks.  B/C projections are shared across heads
(ngroups=1), so their BlockSpec ignores the head index — grouped heads
stream the same [L, N] tiles.

Chunk length L should be a multiple of 8 (sublane) and ideally 128 (lane);
`hd`/`N` are MXU-aligned at 64/128 in the assigned configs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_out_ref, h_ref, *, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)  # [L, hd]
    dt = dt_ref[0, 0].astype(jnp.float32)  # [L]
    a = a_ref[0, 0].astype(jnp.float32)  # [L]
    bm = b_ref[0].astype(jnp.float32)  # [L, N]
    cm = c_ref[0].astype(jnp.float32)  # [L, N]
    h = h_ref[...]  # [hd, N]

    logs = jnp.cumsum(jnp.log(jnp.maximum(a, 1e-30)))  # [L] inclusive
    l = x.shape[0]
    li = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    mi = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    causal = li >= mi
    # mask exponents BEFORE exp: the non-causal region overflows to inf
    decay = jnp.exp(jnp.where(causal, logs[:, None] - logs[None, :], -jnp.inf))
    g = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)  # [L, L]
    w = decay * g * dt[None, :]
    y = jnp.dot(w, x, preferred_element_type=jnp.float32)  # intra-chunk

    # inbound state: y[l] += exp(logs[l]) * C_l . h
    y += jnp.exp(logs)[:, None] * jnp.dot(cm, h.T, preferred_element_type=jnp.float32)

    # chunk-final state: h' = exp(total)*h + x^T @ (B * (tail*dt))
    total = logs[l - 1]
    tail = jnp.exp(total - logs) * dt  # [L]
    h_ref[...] = jnp.exp(total) * h + jnp.dot(
        x.T, bm * tail[:, None], preferred_element_type=jnp.float32
    )
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _final():
        h_out_ref[0, 0] = h_ref[...]


@functools.partial(jax.jit, static_argnums=(5, 6))
def ssd_scan(
    x: jax.Array,  # [B, S, NH, hd]
    dt: jax.Array,  # [B, S, NH]
    a: jax.Array,  # [B, S, NH]
    bm: jax.Array,  # [B, S, N]
    cm: jax.Array,  # [B, S, N]
    chunk: int = 128,
    interpret: bool = True,
):
    """Returns (y [B,S,NH,hd], h_final [B,NH,hd,N])."""
    b, s, nh, hd = x.shape
    n = bm.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0)))
    sp = x.shape[1]
    nc = sp // chunk

    # kernel-friendly layouts: [B, NH, S, *]
    xk = x.swapaxes(1, 2)  # [B, NH, S, hd]
    dtk = dt.transpose(0, 2, 1)  # [B, NH, S]
    ak = a.transpose(0, 2, 1)

    y, h_final = pl.pallas_call(
        functools.partial(_kernel, num_chunks=nc),
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda bb, hh, ci: (bb, hh, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bb, hh, ci: (bb, hh, ci)),
            pl.BlockSpec((1, 1, chunk), lambda bb, hh, ci: (bb, hh, ci)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, ci: (bb, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, ci: (bb, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda bb, hh, ci: (bb, hh, ci, 0)),
            pl.BlockSpec((1, 1, hd, n), lambda bb, hh, ci: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, sp, hd), x.dtype),
            jax.ShapeDtypeStruct((b, nh, hd, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, n), jnp.float32)],
        interpret=interpret,
    )(xk, dtk, ak, bm, cm)
    return y.swapaxes(1, 2)[:, :s], h_final
