"""Pallas TPU kernels for the framework's compute hot-spots.

Each subpackage ships <name>.py (pl.pallas_call + BlockSpec), ops.py
(jit'd wrapper + pure-JAX fallback) and ref.py (jnp oracle):

  knapsack/          the paper's Algorithm 1 at serving batch sizes
  flash_attention/   prefill attention (online softmax, GQA index maps)
  decode_attention/  flash-decoding over ring-buffer KV caches
  ssd_scan/          Mamba2 chunked state-space-dual scan
"""
