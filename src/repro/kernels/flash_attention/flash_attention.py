"""Pallas TPU kernel: flash attention (prefill), GQA-aware.

Grid (B, H, num_q_blocks, num_k_blocks); the K axis is the innermost,
sequential ("arbitrary") dimension — online-softmax statistics (running max
``m``, normalizer ``l``, accumulator ``acc``) live in VMEM scratch and carry
across K steps.  The KV BlockSpec maps the query head to its KV head
(h // group), so grouped heads stream the same K/V block without
materializing a repeat.  Block sizes default to 128 (MXU/VPU aligned).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: int, block_q: int, block_k: int,
    num_k_blocks: int, sq: int, sk: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q + (sk - sq if causal else 0)  # align sequence ends
    k_start = ki * block_k
    # Skip fully-masked blocks (strictly above the causal diagonal / outside
    # the window) — they contribute nothing.
    visible = jnp.asarray(True)
    if causal:
        visible = k_start <= q_start + block_q - 1
    if window > 0:
        visible = jnp.logical_and(visible, k_start + block_k - 1 > q_start - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [bq, bk]
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), bool)
        if causal:
            ok &= cols <= rows
        if window > 0:
            ok &= cols > rows - window
        ok &= cols < sk  # tail padding
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]  # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnums=(3, 4, 5, 6, 7))
def flash_attention(
    q: jax.Array,  # [B, H, Sq, hd]
    k: jax.Array,  # [B, KV, Sk, hd]
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, h, sq, hd = q.shape
    kv, sk = k.shape[1], k.shape[2]
    group = h // kv
    scale = 1.0 / (hd ** 0.5)

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sq_p, sk_p = q.shape[2], k.shape[2]
    nq, nk = sq_p // block_q, sk_p // block_k

    grid = (b, h, nq, nk)
    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            block_q=block_q, block_k=block_k, num_k_blocks=nk, sq=sq, sk=sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bb, hh, qi, ki: (bb, hh // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bb, hh, qi, ki: (bb, hh // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),  # acc
            pltpu.VMEM((block_q, 1), jnp.float32),  # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),  # l (normalizer)
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq]
