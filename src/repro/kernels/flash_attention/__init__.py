from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ops import attend

__all__ = ["flash_attention", "attend"]
