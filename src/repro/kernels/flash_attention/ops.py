"""Jitted wrapper matching the model activation layout [B, S, H, hd]."""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import gqa_attention_ref


def attend(
    q_bshd: jax.Array,  # [B, S, H, hd]
    k_bskh: jax.Array,  # [B, S, KV, hd]
    v_bskh: jax.Array,
    causal: bool = True,
    window: int = 0,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    q = q_bshd.swapaxes(1, 2)
    k = k_bskh.swapaxes(1, 2)
    v = v_bskh.swapaxes(1, 2)
    if use_pallas:
        out = flash_attention(q, k, v, causal, window, interpret=interpret)
    else:
        out = gqa_attention_ref(q, k, v, causal, window)
    return out.swapaxes(1, 2)
