"""Pure-jnp oracle: causal (optionally sliding-window) GQA attention.

Layout: q [B, H, Sq, hd]; k, v [B, KV, Sk, hd]; H = KV * group.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gqa_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    b, h, sq, hd = q.shape
    kv = k.shape[1]
    group = h // kv
    qg = q.reshape(b, kv, group, sq, hd).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    scores = jnp.einsum("bkgqh,bksh->bkgqs", qg, k.astype(jnp.float32)) * scale
    sk = k.shape[2]
    qi = jnp.arange(sq)[:, None] + (sk - sq if causal else 0)  # align ends
    ki = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= ki <= qi
    if window > 0:
        ok &= ki > qi - window
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", probs, v.astype(jnp.float32))
    return out.reshape(b, h, sq, hd).astype(q.dtype)
