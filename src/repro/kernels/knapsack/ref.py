"""Pure-jnp oracle for the batched 0/1-knapsack DP — deliberately kept as
the *take-tensor + backtrack* formulation (the pre-bitmask production
path) so kernel tests compare two independent derivations of Algorithm 1:
a shared bug in the bitmask mask-carry recurrence (core.knapsack and the
Pallas kernel) cannot hide by matching itself.  Test-only: the
``[Q, N, B+1]`` take tensor this allocates is exactly what the serving
paths no longer materialize."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def knapsack_dp_ref(profits: jax.Array, costs: jax.Array, budget: int):
    """profits [Q,N] f32, costs [Q,N] i32 -> (dp_final [Q,B+1], take [Q,N,B+1])."""
    q, n = profits.shape
    bp1 = budget + 1
    js = jnp.arange(bp1, dtype=jnp.int32)

    def item_step(i, carry):
        dp, take = carry
        c = costs[:, i][:, None]
        p = profits[:, i][:, None]
        idx = js[None, :] - c
        prev = jnp.take_along_axis(dp, jnp.maximum(idx, 0), axis=1)
        cand = jnp.where(idx >= 0, prev + p, -jnp.inf)
        tk = cand > dp  # strict: ties keep "not taken" (Algorithm 1 backtrack)
        return jnp.maximum(dp, cand), take.at[:, i].set(tk)

    dp0 = jnp.zeros((q, bp1), jnp.float32)
    take0 = jnp.zeros((q, n, bp1), bool)
    return jax.lax.fori_loop(0, n, item_step, (dp0, take0))


def backtrack(take: jax.Array, costs: jax.Array, budget: int) -> jax.Array:
    """take [Q,N,B+1] bool, costs [Q,N] -> selection mask [Q,N]."""
    q, n, _ = take.shape

    def step(k, carry):
        sel, j = carry
        i = n - 1 - k
        t = take[jnp.arange(q), i, j]
        sel = sel.at[:, i].set(t)
        return sel, j - jnp.where(t, costs[:, i], 0)

    sel0 = jnp.zeros((q, n), bool)
    sel, _ = jax.lax.fori_loop(0, n, step, (sel0, jnp.full((q,), budget, jnp.int32)))
    return sel
