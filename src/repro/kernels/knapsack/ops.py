"""Jitted public API for the knapsack kernel with a pure-JAX fallback."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.knapsack.knapsack import knapsack_dp_pallas
from repro.kernels.knapsack.ref import backtrack, knapsack_dp_ref


def knapsack_select_pallas(
    profits: jax.Array, costs: jax.Array, budget: int, interpret: bool = True
) -> jax.Array:
    """Drop-in replacement for core.knapsack.knapsack_select."""
    _, take = knapsack_dp_pallas(
        jnp.asarray(profits, jnp.float32), jnp.asarray(costs, jnp.int32), budget,
        interpret=interpret,
    )
    return backtrack(take, jnp.asarray(costs, jnp.int32), budget)


def knapsack_select_ref(profits: jax.Array, costs: jax.Array, budget: int) -> jax.Array:
    _, take = knapsack_dp_ref(
        jnp.asarray(profits, jnp.float32), jnp.asarray(costs, jnp.int32), budget
    )
    return backtrack(take, jnp.asarray(costs, jnp.int32), budget)
