"""Jitted public API for the knapsack kernel, plus the independent oracle.

``knapsack_select_pallas`` runs the backtrack-free bitmask DP (see
``core.knapsack``): the kernel emits the packed optimal subset at
``j = budget`` directly, so the host-side work is a single bit-unpack —
no take tensor, no backtrack.  ``knapsack_select_ref`` is the test-only
take-tensor + backtrack formulation kept deliberately different so the
two derivations cross-check each other.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.knapsack import unpack_selection
from repro.kernels.knapsack.knapsack import knapsack_dp_pallas
from repro.kernels.knapsack.ref import backtrack, knapsack_dp_ref


def knapsack_select_pallas(
    profits: jax.Array, costs: jax.Array, budget: int,
    interpret: bool | None = None,
) -> jax.Array:
    """Drop-in replacement for core.knapsack.knapsack_select.

    ``interpret=None`` resolves by backend: the real Mosaic lowering on
    TPU, interpret mode elsewhere (kernel-body semantics on CPU) — so
    ``select_under_budget(..., impl="pallas")`` reaches the compiled
    kernel on TPU without callers threading the flag."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = profits.shape[1]
    _, sel_words = knapsack_dp_pallas(
        jnp.asarray(profits, jnp.float32), jnp.asarray(costs, jnp.int32), budget,
        interpret=interpret,
    )
    return unpack_selection(sel_words, n)


def knapsack_select_ref(profits: jax.Array, costs: jax.Array, budget: int) -> jax.Array:
    """Independent take-tensor + backtrack oracle (test-only; see ref.py)."""
    costs = jnp.asarray(costs, jnp.int32)
    _, take = knapsack_dp_ref(jnp.asarray(profits, jnp.float32), costs, budget)
    return backtrack(take, costs, budget)
