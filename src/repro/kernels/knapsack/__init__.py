from repro.kernels.knapsack.ops import knapsack_select_pallas, knapsack_select_ref

__all__ = ["knapsack_select_pallas", "knapsack_select_ref"]
