"""Pallas TPU kernel: batched 0/1-knapsack forward DP (paper Algorithm 1).

The paper runs its DP once per query on the host; at serving batch sizes the
selection step becomes a per-batch hot spot, so we push the DP onto the TPU:

* one grid program per query *block* — the whole DP row ``dp[0..budget]``
  for ``BQ`` queries stays resident in VMEM (a few KB; VMEM is ~16 MB);
* the item loop is the sequential wavefront; the row update
  ``dp'[j] = max(dp[j], dp[j-c] + p)`` is fully vectorized on the VPU
  (8x128 lanes) — the dynamic shift by ``c`` is a roll + iota mask;
* take-decision bits stream out to HBM; subset recovery is a cheap
  host-side gather (ops.backtrack), keeping the kernel forward-only.

Budget axis should be a multiple of 128 (lane width) for clean tiling;
callers pick ``buckets`` accordingly (cost.normalize_costs default 256).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(profits_ref, costs_ref, dp_ref, take_ref, *, n_items: int, bp1: int):
    # profits_ref/costs_ref: [BQ, N]; dp_ref: [BQ, B+1]; take_ref: [BQ, N, B+1]
    bq = dp_ref.shape[0]
    dp_ref[...] = jnp.zeros((bq, bp1), jnp.float32)
    js = jax.lax.broadcasted_iota(jnp.int32, (bq, bp1), 1)

    def item_step(i, dp):
        c = costs_ref[:, i][:, None]  # [BQ, 1]
        p = profits_ref[:, i][:, None]
        # dp[j - c] via per-row dynamic roll; j < c lanes are invalidated.
        idx = js - c
        shifted = jnp.take_along_axis(dp, jnp.maximum(idx, 0), axis=1)
        cand = jnp.where(idx >= 0, shifted + p, NEG_INF)
        take_ref[:, i, :] = cand > dp
        return jnp.maximum(dp, cand)

    dp = jax.lax.fori_loop(0, n_items, item_step, dp_ref[...])
    dp_ref[...] = dp


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def knapsack_dp_pallas(
    profits: jax.Array,  # [Q, N] float32
    costs: jax.Array,  # [Q, N] int32
    budget: int,
    block_q: int = 8,
    interpret: bool = True,
):
    """Forward DP: returns (dp_final [Q, B+1], take [Q, N, B+1])."""
    q, n = profits.shape
    bp1 = budget + 1
    pad = (-q) % block_q
    if pad:
        profits = jnp.pad(profits, ((0, pad), (0, 0)))
        costs = jnp.pad(costs, ((0, pad), (0, 0)), constant_values=1)
    qp = profits.shape[0]

    grid = (qp // block_q,)
    dp, take = pl.pallas_call(
        functools.partial(_kernel, n_items=n, bp1=bp1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, n), lambda i: (i, 0)),
            pl.BlockSpec((block_q, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, bp1), lambda i: (i, 0)),
            pl.BlockSpec((block_q, n, bp1), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp, bp1), jnp.float32),
            jax.ShapeDtypeStruct((qp, n, bp1), jnp.bool_),
        ],
        interpret=interpret,
    )(profits.astype(jnp.float32), costs.astype(jnp.int32))
    return dp[:q], take[:q]
