"""Pallas TPU kernel: batched 0/1-knapsack bitmask DP (paper Algorithm 1).

The paper runs its DP once per query on the host; at serving batch sizes the
selection step becomes a per-batch hot spot, so we push the DP onto the TPU:

* one grid program per query *block* — the whole DP row ``dp[0..budget]``
  AND the packed selection row (one ``uint32`` word per 32 items per
  capacity) for ``BQ`` queries stay resident in VMEM (a few KB each; VMEM
  is ~16 MB);
* the item loop is the sequential wavefront; the row update
  ``dp'[j] = max(dp[j], dp[j-c] + p)`` and the mask update
  ``mask'[j] = take ? mask[j-c] | (1 << i) : mask[j]`` are fully
  vectorized on the VPU (8x128 lanes) — the dynamic shift by ``c`` is a
  gather over the capacity axis;
* only the final DP row and the packed selection at ``j = budget`` stream
  out to HBM.  There is no ``[N, Q, B+1]`` take tensor and no second
  backtrack loop — the strict improvement test reproduces Algorithm 1's
  ties-keep-not-taken backtrack bit for bit (see ``core.knapsack``).

Budget axis should be a multiple of 128 (lane width) for clean tiling;
callers pick ``buckets`` accordingly (cost.normalize_costs default 256).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.knapsack import mask_words

NEG_INF = -1e30


def _kernel(profits_ref, costs_ref, dp_ref, sel_ref, *, n_items: int, bp1: int,
            n_words: int):
    # profits_ref/costs_ref: [BQ, N]; dp_ref: [BQ, B+1]; sel_ref: [BQ, W] u32
    bq = dp_ref.shape[0]
    js = jax.lax.broadcasted_iota(jnp.int32, (bq, bp1), 1)
    # >= 2-D iota: Mosaic rejects 1-D iota when lowering for real TPUs
    word_ids = jax.lax.broadcasted_iota(jnp.int32, (1, n_words, 1), 1)

    def item_step(i, carry):
        dp, masks = carry  # dp [BQ, B+1]; masks [BQ, W, B+1] uint32
        c = costs_ref[:, i][:, None]  # [BQ, 1]
        p = profits_ref[:, i][:, None]
        # dp[j - c] / mask[j - c] via per-row gather; j < c lanes invalidated.
        idx = js - c
        safe = jnp.maximum(idx, 0)
        shifted_dp = jnp.take_along_axis(dp, safe, axis=1)
        cand = jnp.where(idx >= 0, shifted_dp + p, NEG_INF)
        tk = cand > dp
        shifted_masks = jnp.take_along_axis(
            masks, jnp.broadcast_to(safe[:, None, :], (bq, n_words, bp1)), axis=2
        )
        bit = jnp.where(
            word_ids == i // 32,
            jax.lax.shift_left(jnp.uint32(1), (i % 32).astype(jnp.uint32)),
            jnp.uint32(0),
        )  # [1, W, 1] — broadcasts over queries and capacities
        masks = jnp.where(tk[:, None, :], shifted_masks | bit, masks)
        return jnp.maximum(dp, cand), masks

    dp0 = jnp.zeros((bq, bp1), jnp.float32)
    masks0 = jnp.zeros((bq, n_words, bp1), jnp.uint32)
    dp, masks = jax.lax.fori_loop(0, n_items, item_step, (dp0, masks0))
    dp_ref[...] = dp
    sel_ref[...] = masks[:, :, bp1 - 1]


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def knapsack_dp_pallas(
    profits: jax.Array,  # [Q, N] float32
    costs: jax.Array,  # [Q, N] int32
    budget: int,
    block_q: int = 8,
    interpret: bool = True,
):
    """Bitmask DP: returns (dp_final [Q, B+1], sel_words [Q, W] uint32)."""
    q, n = profits.shape
    bp1 = budget + 1
    w = mask_words(n)
    pad = (-q) % block_q
    if pad:
        profits = jnp.pad(profits, ((0, pad), (0, 0)))
        costs = jnp.pad(costs, ((0, pad), (0, 0)), constant_values=1)
    qp = profits.shape[0]

    grid = (qp // block_q,)
    dp, sel = pl.pallas_call(
        functools.partial(_kernel, n_items=n, bp1=bp1, n_words=w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, n), lambda i: (i, 0)),
            pl.BlockSpec((block_q, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, bp1), lambda i: (i, 0)),
            pl.BlockSpec((block_q, w), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp, bp1), jnp.float32),
            jax.ShapeDtypeStruct((qp, w), jnp.uint32),
        ],
        interpret=interpret,
    )(profits.astype(jnp.float32), costs.astype(jnp.int32))
    return dp[:q], sel[:q]
