from repro.sharding.api import (
    AxisRules,
    axis_rules,
    current_rules,
    logical_constraint,
    logical_sharding,
    param_spec,
)

__all__ = [
    "AxisRules",
    "axis_rules",
    "current_rules",
    "logical_constraint",
    "logical_sharding",
    "param_spec",
]
