"""Parameter sharding inference (GSPMD/FSDP layout rules).

Walks a parameter pytree and assigns every leaf a PartitionSpec from
name/context rules:

* tensor-parallel dims (heads, mlp hidden, experts, vocab) -> ``model``;
* one remaining large dim -> ``fsdp`` (= ("pod","data")) — ZeRO-3-style
  resting shards, gathered just-in-time by GSPMD (or explicitly inside the
  MoE shard_map);
* small leaves (norms, biases, anything < REPLICATE_BELOW elems) replicate;
* stacked per-layer leaves (under a scanned segment) get a leading None.

Every candidate dim is divisibility-checked against the mesh axes it would
occupy; non-divisible annotations are dropped rather than padded.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.api import AxisRules

REPLICATE_BELOW = 1 << 20  # leaves smaller than 1M elements replicate

_SEG_KEYS = {"segs", "blocks", "dec_segs", "enc_segs"}
_ATTN_PARENTS = {"attn", "self_attn", "cross"}
_MLP_PARENTS = {"mlp", "shared", "dense"}

# name -> logical axes, per context
_ATTN_AXES = {
    "wq": ("fsdp", "heads", None),
    "wk": ("fsdp", "kv_heads", None),
    "wv": ("fsdp", "kv_heads", None),
    "wo": ("heads", None, "fsdp"),
    "bq": ("heads", None),
    "bk": ("kv_heads", None),
    "bv": ("kv_heads", None),
    # MLA
    "wdq": ("fsdp", None),
    "wdkv": ("fsdp", None),
    "wkr": ("fsdp", None),
    "wuq": (None, "heads", None),
    "wuk": (None, "heads", None),
    "wuv": (None, "heads", None),
}
_MLP_AXES = {"wi": ("fsdp", "mlp"), "wg": ("fsdp", "mlp"), "wo": ("mlp", "fsdp")}
_EXPERT_AXES = {
    "wi": ("experts", "expert_fsdp", None),
    "wg": ("experts", "expert_fsdp", None),
    "wo": ("experts", "expert_fsdp", None),
}
_SSM_AXES = {
    "in_proj": ("fsdp", "mlp"),
    "conv_w": (None, "mlp"),
    "conv_b": ("mlp",),
    "out_proj": ("mlp", "fsdp"),
}
_TOP_AXES = {
    "embed": ("vocab", "fsdp"),
    "lm_head": ("fsdp", "vocab"),
    "frontend_proj": ("fsdp", None),
    "proj": ("fsdp", None),  # MTP merge projection
}


def _path_names(path) -> list:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
    return names


def _logical_axes_for(path_names: list, shape: Tuple[int, ...]) -> Tuple[Optional[str], ...]:
    name = path_names[-1] if path_names else ""
    parents = set(path_names[:-1])
    if "experts" in parents and name in _EXPERT_AXES:
        return _EXPERT_AXES[name]
    if parents & _ATTN_PARENTS and name in _ATTN_AXES:
        return _ATTN_AXES[name]
    if parents & _MLP_PARENTS and name in _MLP_AXES:
        return _MLP_AXES[name]
    if "ssm" in parents and name in _SSM_AXES:
        return _SSM_AXES[name]
    if name in _TOP_AXES:
        return _TOP_AXES[name]
    return (None,) * len(shape)


def _check_divisible(spec_axes, shape, rules: AxisRules) -> P:
    parts = []
    used: set = set()
    for dim, logical in zip(shape, spec_axes):
        if logical is None:
            parts.append(None)
            continue
        mesh_axes = rules.rules.get(logical)
        if mesh_axes is None:
            parts.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        mesh_axes = tuple(a for a in mesh_axes if a not in used)
        # drop trailing axes until divisible
        while mesh_axes:
            prod = int(np.prod([rules.mesh.shape[a] for a in mesh_axes]))
            if dim % prod == 0:
                break
            mesh_axes = mesh_axes[:-1]
        if not mesh_axes:
            parts.append(None)
            continue
        used.update(mesh_axes)
        parts.append(mesh_axes[0] if len(mesh_axes) == 1 else tuple(mesh_axes))
    return P(*parts)


def infer_param_specs(params: Any, rules: AxisRules) -> Any:
    """PartitionSpec pytree matching ``params``."""

    def leaf_spec(path, leaf):
        shape = np.shape(leaf)
        if int(np.prod(shape) or 1) < REPLICATE_BELOW:
            return P()
        names = _path_names(path)
        stacked = bool(set(names) & _SEG_KEYS)
        if stacked and len(shape) >= 1:
            axes = _logical_axes_for(names, shape[1:])
            axes = (None,) + tuple(axes)
        else:
            axes = _logical_axes_for(names, shape)
        if len(axes) != len(shape):
            axes = tuple(axes[: len(shape)]) + (None,) * (len(shape) - len(axes))
        return _check_divisible(axes, shape, rules)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def param_shardings(params: Any, rules: AxisRules) -> Any:
    specs = infer_param_specs(params, rules)
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def spec_drop_dim(spec: P, rank: int, dim: int) -> P:
    """Spec for a reduced tensor missing dim ``dim`` of a rank-``rank``
    tensor (Adafactor factored states)."""
    parts = list(spec) + [None] * (rank - len(spec))
    del parts[dim % rank]
    return P(*parts)
