"""Logical-axis sharding rules (MaxText-style).

Model code annotates activations/params with *logical* axis names
("batch", "seq", "heads", "mlp", "experts", "vocab", ...).  The launcher
installs an :class:`AxisRules` mapping logical names onto mesh axes for the
current mesh; outside any rules context every annotation is a no-op, so the
same model code runs unchanged in single-device tests and in the 512-chip
dry-run.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping from logical axis names to (possibly composite) mesh axes."""

    mesh: Mesh
    rules: Mapping[str, MeshAxes]

    def resolve(self, logical_axes: Sequence[Optional[str]]) -> P:
        parts = []
        used: set = set()
        for name in logical_axes:
            if name is None:
                parts.append(None)
                continue
            mesh_axes = self.rules.get(name)
            if mesh_axes is None:
                parts.append(None)
                continue
            if isinstance(mesh_axes, str):
                mesh_axes = (mesh_axes,)
            # A mesh axis may appear at most once in a PartitionSpec.
            fresh = tuple(a for a in mesh_axes if a not in used)
            used.update(fresh)
            if not fresh:
                parts.append(None)
            elif len(fresh) == 1:
                parts.append(fresh[0])
            else:
                parts.append(fresh)
        return P(*parts)


class _State(threading.local):
    def __init__(self) -> None:
        self.stack: list = []


_STATE = _State()


@contextlib.contextmanager
def axis_rules(rules: AxisRules):
    """Install ``rules`` for the dynamic extent of the context."""
    _STATE.stack.append(rules)
    try:
        yield rules
    finally:
        _STATE.stack.pop()


def current_rules() -> Optional[AxisRules]:
    return _STATE.stack[-1] if _STATE.stack else None


def logical_sharding(*logical_axes: Optional[str]) -> Optional[NamedSharding]:
    """NamedSharding for the given logical axes under the current rules."""
    rules = current_rules()
    if rules is None:
        return None
    return NamedSharding(rules.mesh, rules.resolve(logical_axes))


def logical_constraint(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` under the current rules (no-op without)."""
    sharding = logical_sharding(*logical_axes)
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


def param_spec(*logical_axes: Optional[str]) -> P:
    """PartitionSpec for a parameter with the given logical axes."""
    rules = current_rules()
    if rules is None:
        return P()
    return rules.resolve(logical_axes)


# Default logical->mesh rules used by the production launcher.  ``data``
# carries the batch dimension (and the ``pod`` axis when multi-pod);
# ``model`` carries tensor-parallel dims: attention heads, MLP hidden,
# experts and the vocab dimension of embeddings / logits.
DEFAULT_RULES: Mapping[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "vocab": "model",
    "state": None,
    "conv": None,
    # sequence parallelism: shard the residual stream's seq dim over `model`
    # between blocks (Megatron SP). Off by default; the training dry-run
    # enables it — it shrinks the per-layer saved activations 16x at the
    # cost of per-layer all-gather/reduce-scatter pairs (EXPERIMENTS §Perf).
    "act_seq": None,
    # decode: shard the cache length over the model axis (flash-decoding
    # style) — kv-head counts are often < mesh model size, cache length never.
    "cache_seq": "model",
    # parameter FSDP axis (ZeRO-3): weights gathered just-in-time per layer.
    "fsdp": ("pod", "data"),
    # expert weights keep their own FSDP name so serving can replicate the
    # (small) non-expert weights while the expert bank stays sharded.
    "expert_fsdp": ("pod", "data"),
}


def partition_devices(devices: Sequence, n_groups: int) -> Tuple[Tuple, ...]:
    """Split a flat device list into ``n_groups`` contiguous equal groups.

    The cluster placement layer treats each group as one logical *host*:
    contiguous slices keep physically-adjacent devices (which JAX orders
    by process/slice) on the same host, so intra-host collectives never
    cross a host boundary.  Requires ``len(devices)`` divisible by
    ``n_groups`` — a ragged split would give hosts different mesh shapes
    and break bucket reuse across placements."""
    if n_groups < 1:
        raise ValueError("n_groups must be >= 1")
    if len(devices) % n_groups != 0:
        raise ValueError(
            f"{len(devices)} devices do not split evenly into {n_groups} hosts"
        )
    per = len(devices) // n_groups
    return tuple(tuple(devices[i * per:(i + 1) * per]) for i in range(n_groups))


def host_mesh(devices: Sequence, axes: Tuple[str, str] = ("data", "model"),
              model_parallel: Optional[int] = None) -> Mesh:
    """A per-host mesh over one host's devices.

    ``model_parallel`` fixes the size of the second (tensor-parallel)
    axis; by default every device on the host goes to ``model`` — the
    serving layer batches over hosts, not within one."""
    n = len(devices)
    if n == 0:
        raise ValueError("cannot build a mesh over zero devices")
    mp = n if model_parallel is None else model_parallel
    if mp < 1 or n % mp != 0:
        raise ValueError(f"model_parallel={mp} does not divide {n} devices")
    arr = np.asarray(devices, dtype=object).reshape(n // mp, mp)
    return Mesh(arr, axes)


def default_axis_rules(mesh: Mesh, overrides: Optional[Mapping[str, MeshAxes]] = None) -> AxisRules:
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    # Drop references to mesh axes that do not exist on this mesh.
    names = set(mesh.axis_names)

    def _filter(v: MeshAxes) -> MeshAxes:
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in names else None
        kept = tuple(a for a in v if a in names)
        return kept if kept else None

    return AxisRules(mesh=mesh, rules={k: _filter(v) for k, v in rules.items()})
