"""Greedy generation for decoder-only and encoder-decoder models.

Prompts are right-padded; padded slots get position -1 so they are masked
out of attention and dropped from the KV cache (see models.attention).
The decode loop is a single jitted ``lax.scan`` over ``max_new`` steps.

Two entry styles share the same loop bodies:

* :func:`greedy_generate` / :func:`greedy_generate_encdec` — ad-hoc jit
  per (shape, max_new); the cache is allocated inside the jit.  Simple,
  but every new shape recompiles and reallocates.
* ``decoder_generate_with_cache`` / ``encdec_generate_with_cache`` — the
  cache is a caller-owned argument and is returned, so
  :mod:`repro.serve.dispatch` can jit them once per shape *bucket* with
  ``donate_argnums`` on the cache: steady-state traffic reuses the same
  HBM buffers with zero recompiles and zero reallocations.

Caches carried across calls hold stale state; :func:`reset_cache` clears
exactly what could leak (position slots and SSM recurrent state) at the
top of each jitted body — KV values are masked by position and need no
clearing.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import TOKENIZER
from repro.models.encdec import EncDecLM
from repro.models.transformer import DecoderLM


def prompt_positions(tokens: jax.Array, pad_id: int) -> Tuple[jax.Array, jax.Array]:
    """Positions [B,S] with -1 at pads, plus per-row lengths [B]."""
    real = tokens != pad_id
    lengths = jnp.sum(real, axis=1).astype(jnp.int32)
    pos = jnp.cumsum(real.astype(jnp.int32), axis=1) - 1
    return jnp.where(real, pos, -1), lengths


def reset_cache(cache: dict) -> dict:
    """Make a previously-used decode cache safe for a fresh generation.

    Only state that masking cannot neutralize is cleared: ``pos`` slots
    (-1 = empty — stale positions would be attended) and SSM recurrent
    state (``h``/``conv`` accumulate across steps).  Stale K/V values are
    unreachable once their slot's ``pos`` is -1, so they are left in
    place — under ``donate_argnums`` this makes the reset a cheap fused
    in-place init rather than a full-cache rewrite."""

    def reset(path, leaf):
        name = path[-1].key if path and hasattr(path[-1], "key") else None
        if name == "pos":
            return jnp.full_like(leaf, -1)
        if name in ("h", "conv"):
            return jnp.zeros_like(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(reset, cache)


def decoder_generate_with_cache(
    model: DecoderLM,
    params: dict,
    prompt: jax.Array,  # [B, Sp] right-padded
    cache: dict,  # model.init_cache(B, Sp + max_new + frontend_tokens)
    max_new: int,
    pad_id: int,
    eos_id: int,
) -> Tuple[jax.Array, dict]:
    """Shared greedy-decode body; returns (tokens [B, max_new], final cache)."""
    b, sp = prompt.shape
    cache = reset_cache(cache)
    positions, lengths = prompt_positions(prompt, pad_id)
    # Full-forward prefill: right-padded prompts need the logits at each
    # row's last *real* token (not the last column), so gather per row.
    logits_all, cache, _, _ = model.forward(params, prompt, cache=cache, positions=positions)
    off = model.cfg.frontend_tokens
    gather_idx = jnp.maximum(off + lengths - 1, 0)[:, None, None]
    last = jnp.take_along_axis(
        logits_all, jnp.broadcast_to(gather_idx, (b, 1, logits_all.shape[-1])), axis=1
    )
    tok0 = jnp.argmax(last[:, 0], axis=-1).astype(jnp.int32)

    def step(carry, _):
        tok, pos, cache, done = carry
        out_tok = jnp.where(done, pad_id, tok)
        logits, cache = model.decode_step(params, tok[:, None], pos, cache)
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        done_next = done | (tok == eos_id)
        nxt = jnp.where(done_next, pad_id, nxt)
        return (nxt, pos + 1, cache, done_next), out_tok

    pos0 = lengths + off
    done0 = tok0 == eos_id
    (_, _, cache, _), toks = jax.lax.scan(
        step, (tok0, pos0, cache, done0), None, length=max_new
    )
    return toks.swapaxes(0, 1), cache  # [B, max_new]


@functools.partial(jax.jit, static_argnums=(0, 3, 4, 5))
def _generate_decoder(
    model: DecoderLM,
    params: dict,
    prompt: jax.Array,  # [B, Sp] right-padded
    max_new: int,
    pad_id: int,
    eos_id: int,
) -> jax.Array:
    b, sp = prompt.shape
    cache = model.init_cache(b, sp + max_new + model.cfg.frontend_tokens)
    toks, _ = decoder_generate_with_cache(
        model, params, prompt, cache, max_new, pad_id, eos_id
    )
    return toks


def greedy_generate(
    model: DecoderLM,
    params: dict,
    prompt: np.ndarray,
    max_new: int = 32,
    pad_id: int = TOKENIZER.pad_id,
    eos_id: int = TOKENIZER.eos_id,
) -> np.ndarray:
    return np.asarray(
        _generate_decoder(model, params, jnp.asarray(prompt, jnp.int32), max_new, pad_id, eos_id)
    )


def encdec_generate_with_cache(
    model: EncDecLM,
    params: dict,
    enc_tokens: jax.Array,  # [B, Se]
    cache: dict,  # model.init_cache(B, max_new + 2, enc_seq=Se)
    max_new: int,
    pad_id: int,
    eos_id: int,
    bos_id: int,
) -> Tuple[jax.Array, dict]:
    """Shared encdec greedy body; returns (tokens [B, max_new], final cache)."""
    b = enc_tokens.shape[0]
    cache = reset_cache(cache)
    bos = jnp.full((b, 1), bos_id, jnp.int32)
    logits, cache = model.prefill(params, bos, cache, enc_tokens=enc_tokens)
    tok0 = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)

    def step(carry, i):
        tok, cache, done = carry
        out_tok = jnp.where(done, pad_id, tok)
        pos = jnp.full((b,), 0, jnp.int32) + i + 1
        logits, cache = model.decode_step(params, tok[:, None], pos, cache)
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        done_next = done | (tok == eos_id)
        nxt = jnp.where(done_next, pad_id, nxt)
        return (nxt, cache, done_next), out_tok

    (_, cache, _), toks = jax.lax.scan(
        step, (tok0, cache, tok0 == eos_id), jnp.arange(max_new)
    )
    return toks.swapaxes(0, 1), cache


def encdec_prefill_with_cache(
    model: EncDecLM,
    params: dict,
    enc_tokens: jax.Array,  # [B, Se]
    cache: dict,  # model.init_cache(B, max_new + 2, enc_seq=Se)
    eos_id: int,
    bos_id: int,
) -> Tuple[jax.Array, jax.Array, dict]:
    """Prefill half of the streaming decode loop: encoder forward + BOS
    decoder step, exactly as :func:`encdec_generate_with_cache` does before
    its scan.  Returns ``(tok0 [B], done0 [B], cache)`` — the state a row
    carries into its first :func:`encdec_decode_step`.  Disaggregating this
    from the step body is what lets a long prompt prefill outside the
    shared decode loop (it never stalls rows already decoding)."""
    b = enc_tokens.shape[0]
    cache = reset_cache(cache)
    bos = jnp.full((b, 1), bos_id, jnp.int32)
    logits, cache = model.prefill(params, bos, cache, enc_tokens=enc_tokens)
    tok0 = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
    return tok0, tok0 == eos_id, cache


def encdec_decode_step(
    model: EncDecLM,
    params: dict,
    tok: jax.Array,  # [B] carry token per slot
    pos: jax.Array,  # [B] decode position per slot (1 at the first step)
    done: jax.Array,  # [B] bool; True for finished AND vacant slots
    cache: dict,
    pad_id: int,
    eos_id: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, dict]:
    """One decode step over a persistent in-flight batch: the scan body of
    :func:`encdec_generate_with_cache`, lifted out so rows can join and
    leave between steps.  ``done`` doubles as the leave/vacancy mask — a
    finished or empty slot emits ``pad_id`` and feeds ``pad_id`` forward,
    so its math can never perturb live rows (rows are independent).
    ``pos`` is per-row, so co-resident rows may be at different depths.
    Returns ``(emitted, next_tok, pos + 1, done_next, cache)``; a row's
    emitted sequence is bit-identical to the batch-boundary scan's."""
    out_tok = jnp.where(done, pad_id, tok)
    logits, cache = model.decode_step(params, tok[:, None], pos, cache)
    nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
    done_next = done | (tok == eos_id)
    nxt = jnp.where(done_next, pad_id, nxt)
    return out_tok, nxt, pos + 1, done_next, cache


@functools.partial(jax.jit, static_argnums=(0, 3, 4, 5, 6))
def _generate_encdec(
    model: EncDecLM,
    params: dict,
    enc_tokens: jax.Array,  # [B, Se]
    max_new: int,
    pad_id: int,
    eos_id: int,
    bos_id: int,
) -> jax.Array:
    b, se = enc_tokens.shape
    cache = model.init_cache(b, max_new + 2, enc_seq=se)
    toks, _ = encdec_generate_with_cache(
        model, params, enc_tokens, cache, max_new, pad_id, eos_id, bos_id
    )
    return toks


def greedy_generate_encdec(
    model: EncDecLM,
    params: dict,
    enc_tokens: np.ndarray,
    max_new: int = 32,
    pad_id: int = TOKENIZER.pad_id,
    eos_id: int = TOKENIZER.eos_id,
    bos_id: int = TOKENIZER.bos_id,
) -> np.ndarray:
    return np.asarray(
        _generate_encdec(
            model, params, jnp.asarray(enc_tokens, jnp.int32), max_new, pad_id, eos_id, bos_id
        )
    )
