"""Deterministic, seed-driven traffic simulator for the serving stack.

Discrete-event load generator over the continuous-batching
:class:`~repro.serve.scheduler.Scheduler`: a :class:`Scenario` describes
an arrival process (steady / bursty / heavy-tail), a weighted mix of
per-request overrides (policy, budget, priority, deadline), and an
optional failure-injection schedule; :class:`TrafficSimulator` drives the
scheduler tick-by-tick and returns a :class:`TrafficReport` with
per-request latencies, deadline-miss and shed counters, and the
scheduler's full event trace.

Everything is deterministic given ``Scenario.seed``: arrival ticks, mix
draws, simulated member responses (``SimBackend`` keys its RNG on the
query, not the batch), and injected failures (keyed on per-member call
counts, not wall time).  Two runs of the same scenario produce identical
traces — ``TrafficReport.trace`` is replayable byte for byte — and the
fused responses are byte-identical to one offline
``EnsembleServer.serve_requests`` call over the same requests, which is
what ``tests/test_traffic_scenarios.py`` pins.

The simulator is both the load generator behind
``benchmarks/serve_bench.py --scenario ...`` and the engine of the
scenario test suite.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.data.mixinstruct import Record
from repro.serve.api import EnsembleRequest, EnsembleResponse
from repro.serve.backends import FailureInjector
from repro.serve.scheduler import Scheduler


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """When requests arrive, in scheduler ticks.

    * ``steady`` — ``rate`` requests per tick, evenly spaced
      (request *i* arrives at tick ``floor(i / rate)``).
    * ``bursty`` — bursts of ``burst_size`` requests every
      ``burst_every`` ticks, nothing in between.
    * ``heavy-tail`` — inter-arrival gaps drawn from a Pareto
      distribution (shape ``tail_shape``, clamped at ``tail_cap``):
      long quiet stretches punctured by arrival clumps.
    """

    kind: str = "steady"
    rate: float = 1.0
    burst_size: int = 8
    burst_every: int = 8
    tail_shape: float = 1.2
    tail_cap: int = 32

    def arrival_ticks(self, n: int, rng: np.random.Generator) -> List[int]:
        if self.kind == "steady":
            return [int(i / self.rate) for i in range(n)]
        if self.kind == "bursty":
            return [(i // self.burst_size) * self.burst_every for i in range(n)]
        if self.kind == "heavy-tail":
            ticks, t = [], 0
            for _ in range(n):
                ticks.append(t)
                t += min(int(rng.pareto(self.tail_shape)), self.tail_cap)
            return ticks
        raise ValueError(
            f"unknown arrival kind {self.kind!r}; "
            "expected 'steady', 'bursty', or 'heavy-tail'"
        )


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One reproducible traffic scenario.

    ``mix`` is a weighted tuple of request-override dicts (any subset of
    ``budget`` / ``policy`` / ``policy_kwargs`` / ``priority`` /
    ``deadline_ticks`` / ``max_new_tokens``); each arrival draws one
    entry.  ``deadline_ticks`` is the default deadline for requests whose
    mix entry does not set its own.  ``failures`` maps a pool member to
    the 0-based call indices that raise (see
    :class:`~repro.serve.backends.FailureInjector`)."""

    name: str
    arrivals: ArrivalProcess = ArrivalProcess()
    n_requests: int = 24
    seed: int = 0
    mix: Tuple[Tuple[float, Mapping[str, Any]], ...] = ()
    deadline_ticks: Optional[int] = None
    failures: Tuple[Tuple[int, Tuple[int, ...]], ...] = ()


def build_arrivals(scenario: Scenario,
                   records: Sequence[Record]) -> List[Tuple[int, EnsembleRequest]]:
    """The scenario's deterministic arrival schedule: (tick, request) pairs,
    non-decreasing in tick.  Records cycle in order, so request *i* always
    carries ``records[i % len(records)]`` — the offline-equivalence tests
    rely on this mapping."""
    if not records:
        raise ValueError("need at least one record to build traffic from")
    rng = np.random.default_rng(scenario.seed)
    ticks = scenario.arrivals.arrival_ticks(scenario.n_requests, rng)
    weights = np.asarray([w for w, _ in scenario.mix], np.float64)
    if scenario.mix:
        weights = weights / weights.sum()
    out = []
    for i, tick in enumerate(ticks):
        overrides: Dict[str, Any] = {}
        if scenario.mix:
            overrides = dict(scenario.mix[int(rng.choice(len(scenario.mix),
                                                         p=weights))][1])
        if "deadline_ticks" not in overrides and scenario.deadline_ticks is not None:
            overrides["deadline_ticks"] = scenario.deadline_ticks
        rec = records[i % len(records)]
        out.append((tick, EnsembleRequest(query=rec.query, record=rec, **overrides)))
    return out


@dataclasses.dataclass
class TrafficReport:
    """What one simulated run produced, in arrival order."""

    scenario: str
    requests: List[EnsembleRequest]
    responses: List[Optional[EnsembleResponse]]  # None where shed/failed
    errors: List[Optional[BaseException]]
    latency_ticks: List[Optional[int]]  # dispatch tick - arrival tick
    wall_latency_s: List[Optional[float]]
    deadline_missed: List[bool]
    trace: List[dict]  # the scheduler's deterministic event log
    stats: Dict[str, int]  # scheduler counters at end of run
    compiles: Dict[str, int]  # engine generate-compile counters
    ticks: int  # total scheduler ticks consumed

    # -- summary metrics -------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.requests)

    @property
    def served(self) -> int:
        return sum(r is not None for r in self.responses)

    @property
    def shed_rate(self) -> float:
        return self.stats.get("shed", 0) / max(self.n, 1)

    @property
    def deadline_miss_rate(self) -> float:
        return sum(self.deadline_missed) / max(self.n, 1)

    def latency_percentiles(self, qs=(50, 99)) -> Dict[str, float]:
        """p50/p99 (by default) over wall-clock and tick latencies of the
        served requests."""
        walls = [w for w in self.wall_latency_s if w is not None]
        ticks = [t for t in self.latency_ticks if t is not None]
        out: Dict[str, float] = {}
        for q in qs:
            out[f"p{q}_latency_s"] = float(np.percentile(walls, q)) if walls else 0.0
            out[f"p{q}_latency_ticks"] = (
                float(np.percentile(ticks, q)) if ticks else 0.0)
        return out


class TrafficSimulator:
    """Drives a Scheduler through one Scenario, tick by tick."""

    def __init__(self, scheduler: Scheduler, scenario: Scenario,
                 records: Sequence[Record]):
        self.scheduler = scheduler
        self.scenario = scenario
        self.records = list(records)
        if scenario.failures:
            # always wrap fresh around the innermost backend: a reused
            # server keeps neither a previous scenario's schedule nor its
            # consumed call counters, so replay() stays byte-identical
            backend = scheduler.server.backend
            if isinstance(backend, FailureInjector):
                backend = backend.inner
            scheduler.server.backend = FailureInjector(
                backend, failures={m: tuple(calls)
                                   for m, calls in scenario.failures})

    def run(self, max_idle_ticks: int = 1000) -> TrafficReport:
        """Submit the arrival schedule against the scheduler's clock and
        tick until every future resolves.  Engine-side batch failures are
        recorded per request (futures are always resolved), never raised —
        a scenario run always completes."""
        sched = self.scheduler
        arrivals = build_arrivals(self.scenario, self.records)
        futures: List = []
        submit_s: List[float] = []
        done_s: List[Optional[float]] = []
        requests = [req for _, req in arrivals]

        def stamp():
            t = time.perf_counter()
            for i, f in enumerate(futures):
                if f.done() and done_s[i] is None:
                    done_s[i] = t

        idx = 0
        idle = 0
        while idx < len(arrivals) or sched.pending:
            while idx < len(arrivals) and arrivals[idx][0] <= sched.now:
                submit_s.append(time.perf_counter())
                done_s.append(None)
                try:
                    futures.append(sched.submit(arrivals[idx][1]))
                except Exception:
                    # an inline dispatch crashed past hedging: the batch's
                    # futures (possibly including ours) are resolved with
                    # the cause; recover the handle so the report still
                    # accounts for this request
                    if sched.last_submitted is None:
                        raise  # validation error — a sim bug, surface it
                    futures.append(sched.last_submitted)
                idx += 1
                stamp()
            before = sched.pending
            try:
                sched.tick()
            except Exception:
                pass  # batch futures already resolved with the cause
            stamp()
            idle = idle + 1 if sched.pending == before and idx >= len(arrivals) else 0
            if idle > max_idle_ticks:
                raise RuntimeError(
                    f"simulator failed to drain: {sched.pending} requests "
                    f"still pending after {max_idle_ticks} idle ticks")
        stamp()

        latency_ticks: List[Optional[int]] = [None] * len(futures)
        missed = [False] * len(futures)
        seq_to_i = {f.seq: i for i, f in enumerate(futures)}
        for ev in sched.events:
            if ev["event"] == "complete" and ev["req"] in seq_to_i:
                i = seq_to_i[ev["req"]]
                latency_ticks[i] = ev["latency_ticks"]
                missed[i] = ev["missed"]
        responses: List[Optional[EnsembleResponse]] = []
        errors: List[Optional[BaseException]] = []
        walls: List[Optional[float]] = []
        for i, f in enumerate(futures):
            err = f._error
            responses.append(f._response if err is None else None)
            errors.append(err)
            walls.append(done_s[i] - submit_s[i]
                         if err is None and done_s[i] is not None else None)
        return TrafficReport(
            scenario=self.scenario.name,
            requests=requests,
            responses=responses,
            errors=errors,
            latency_ticks=latency_ticks,
            wall_latency_s=walls,
            deadline_missed=missed,
            trace=list(sched.events),
            stats=dict(sched.stats),
            compiles=sched.server.generate_compiles(),
            ticks=sched.now,
        )


def replay(scheduler_factory, scenario: Scenario,
           records: Sequence[Record]) -> TrafficReport:
    """Re-run a scenario from scratch on a fresh scheduler.  Because every
    source of variation is seed-keyed, the returned report's trace is
    byte-identical to the original run's."""
    return TrafficSimulator(scheduler_factory(), scenario, records).run()


def preset_scenarios(n_requests: int = 24, seed: int = 0) -> Dict[str, Scenario]:
    """The four named scenarios the benchmarks and the scenario test suite
    share.  ``failure`` injects a transient fault on member 3 (one of the
    two members modi@0.2 reliably selects under the default stack seeds),
    so hedged retry actually fires; every future still resolves."""
    return {
        "steady": Scenario(
            name="steady",
            arrivals=ArrivalProcess("steady", rate=2.0),
            n_requests=n_requests, seed=seed, deadline_ticks=4,
        ),
        "bursty": Scenario(
            name="bursty",
            arrivals=ArrivalProcess("bursty", burst_size=8, burst_every=6),
            n_requests=n_requests, seed=seed, deadline_ticks=3,
            mix=(
                (0.7, {}),
                (0.2, {"budget": 0.5, "priority": 1}),
                (0.1, {"policy": "best-single", "priority": 2,
                       "deadline_ticks": 1}),
            ),
        ),
        "heavy-tail": Scenario(
            name="heavy-tail",
            arrivals=ArrivalProcess("heavy-tail", tail_shape=1.1),
            n_requests=n_requests, seed=seed, deadline_ticks=6,
            mix=(
                (0.6, {}),
                (0.3, {"budget": 0.6}),
                (0.1, {"policy": "llm-blender", "priority": 3}),
            ),
        ),
        "failure": Scenario(
            name="failure",
            arrivals=ArrivalProcess("steady", rate=2.0),
            n_requests=n_requests, seed=seed, deadline_ticks=4,
            failures=((3, (1,)),),
        ),
    }
