"""Deterministic, seed-driven traffic simulator for the serving stack.

Discrete-event load generator over the continuous-batching
:class:`~repro.serve.scheduler.Scheduler`: a :class:`Scenario` describes
an arrival process (steady / bursty / heavy-tail / diurnal), a weighted
mix of per-request overrides (policy, budget, priority, deadline), and
optional failure-injection schedules — per-member call faults
(:class:`~repro.serve.backends.FailureInjector`) and whole-host outages
(routed through a :class:`~repro.serve.cluster.ClusterRouter` over an
auto-built :class:`~repro.serve.cluster.PlacementPlan`);
:class:`TrafficSimulator` drives the scheduler tick-by-tick and returns
a :class:`TrafficReport` with per-request latencies, deadline-miss and
shed counters, and the scheduler's full event trace.

Everything is deterministic given ``Scenario.seed``: arrival ticks, mix
draws, simulated member responses (``SimBackend`` keys its RNG on the
query, not the batch), and injected failures (keyed on per-member call
counts and per-host dispatch counts, not wall time).  Two runs of the
same scenario produce identical traces — ``TrafficReport.trace`` is
replayable byte for byte, in both sync and async dispatch modes — and
the fused responses are byte-identical to one offline
``EnsembleServer.serve_requests`` call over the same requests, which is
what ``tests/test_traffic_scenarios.py`` pins.

Beyond the logical clock, every run records ``arrival_wall_ns`` per
request — the monotonic wall-clock instant it was submitted — so a
production run's arrival process can be captured
(:meth:`TrafficReport.captured`) and re-driven against a new build with
:meth:`TrafficSimulator.replay`, optionally time-scaled.

The simulator is both the load generator behind
``benchmarks/serve_bench.py --scenario ...`` and the engine of the
scenario test suite.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.data.mixinstruct import Record
from repro.serve.api import EnsembleRequest, EnsembleResponse
from repro.serve.backends import FailureInjector
from repro.serve.cluster import ClusterRouter, HealthMonitor, PlacementPlan
from repro.serve.scheduler import Scheduler

DEFAULT_HOSTS = 4  # hosts for scenarios that inject host faults without a count


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """When requests arrive, in scheduler ticks.

    * ``steady`` — ``rate`` requests per tick, evenly spaced
      (request *i* arrives at tick ``floor(i / rate)``).
    * ``bursty`` — bursts of ``burst_size`` requests every
      ``burst_every`` ticks, nothing in between.
    * ``heavy-tail`` — inter-arrival gaps drawn from a Pareto
      distribution (shape ``tail_shape``, clamped at ``tail_cap``):
      long quiet stretches punctured by arrival clumps.
    * ``diurnal`` — a deterministic load curve: the per-tick rate swings
      sinusoidally around ``rate`` with relative ``amplitude`` over a
      ``period``-tick day, emitting an arrival whenever the accumulated
      rate crosses 1 — peak-hour clumps, trough-hour quiet.
    """

    kind: str = "steady"
    rate: float = 1.0
    burst_size: int = 8
    burst_every: int = 8
    tail_shape: float = 1.2
    tail_cap: int = 32
    period: int = 24  # diurnal day length, in ticks
    amplitude: float = 0.8  # diurnal swing as a fraction of `rate`

    def arrival_ticks(self, n: int, rng: np.random.Generator) -> List[int]:
        if self.kind == "steady":
            return [int(i / self.rate) for i in range(n)]
        if self.kind == "bursty":
            return [(i // self.burst_size) * self.burst_every for i in range(n)]
        if self.kind == "heavy-tail":
            ticks, t = [], 0
            for _ in range(n):
                ticks.append(t)
                t += min(int(rng.pareto(self.tail_shape)), self.tail_cap)
            return ticks
        if self.kind == "diurnal":
            if self.rate <= 0:
                raise ValueError("diurnal arrivals need rate > 0")
            ticks: List[int] = []
            acc, t = 0.0, 0
            while len(ticks) < n:
                lam = self.rate * (
                    1.0 + self.amplitude * float(np.sin(2.0 * np.pi * t / self.period))
                )
                acc += max(lam, 0.0)
                while acc >= 1.0 and len(ticks) < n:
                    ticks.append(t)
                    acc -= 1.0
                t += 1
            return ticks
        raise ValueError(
            f"unknown arrival kind {self.kind!r}; "
            "expected 'steady', 'bursty', 'heavy-tail', or 'diurnal'"
        )


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One reproducible traffic scenario.

    ``mix`` is a weighted tuple of request-override dicts (any subset of
    ``budget`` / ``policy`` / ``policy_kwargs`` / ``priority`` /
    ``deadline_ticks`` / ``max_new_tokens``); each arrival draws one
    entry.  ``deadline_ticks`` is the default deadline for requests whose
    mix entry does not set its own.  ``failures`` maps a pool member to
    the 0-based call indices that raise (see
    :class:`~repro.serve.backends.FailureInjector`).

    ``hosts`` shards the pool over that many logical hosts through a
    greedy-balanced :class:`~repro.serve.cluster.PlacementPlan` (the
    simulator wraps the backend in a
    :class:`~repro.serve.cluster.ClusterRouter`); ``host_failures`` maps
    a host id to the 0-based *dispatch* indices at which that whole host
    dies mid-scenario — the correlated-failure counterpart of
    ``failures``.  ``host_recoveries`` maps a host id to the logical
    ticks at which it comes back up (re-admitted after
    ``probation_ticks`` more ticks of probation); ``replicas`` places
    each member on that many distinct hosts, ``rebalance`` re-places
    members that lost replica redundancy at the next maintenance tick,
    and ``fanout`` serves a batch's per-host shards concurrently on the
    router's executor pool — all without changing a single output byte
    (fan-out and recovery are routing concerns; the chaos suite pins
    byte-equivalence against sequential routing per preset).

    Probe-driven health (``probe_interval`` set) installs a
    :class:`~repro.serve.cluster.HealthMonitor`: ``host_recoveries``
    then describes when each host's *underlying* health returns (the
    monitor revives it through a half-open probe at the next probe
    tick, no probation schedule involved), ``probe_failures`` is the
    breaker's consecutive-failure threshold, and ``probe_faults`` maps
    a host to the probe indices that fail regardless of health — one
    index is a flaky probe, a threshold-long run is a crash-on-probe
    kill.  Grey failures: ``slow`` maps a member to the call indices
    that straggle for ``slow_s`` wall seconds (never changing the
    logical trace), ``host_stragglers`` maps a host to the grey-slow
    dispatch indices that ``hedge_stragglers=True`` re-routes to a
    replica at consume time, and ``shard_deadline_s`` arms the fan-out
    router's wall-clock shard deadline."""

    name: str
    arrivals: ArrivalProcess = ArrivalProcess()
    n_requests: int = 24
    seed: int = 0
    mix: Tuple[Tuple[float, Mapping[str, Any]], ...] = ()
    deadline_ticks: Optional[int] = None
    failures: Tuple[Tuple[int, Tuple[int, ...]], ...] = ()
    hosts: Optional[int] = None
    host_failures: Tuple[Tuple[int, Tuple[int, ...]], ...] = ()
    host_recoveries: Tuple[Tuple[int, Tuple[int, ...]], ...] = ()
    probation_ticks: int = 0
    replicas: int = 1
    rebalance: bool = False
    fanout: bool = False
    probe_interval: Optional[int] = None
    probe_failures: int = 2
    probe_faults: Tuple[Tuple[int, Tuple[int, ...]], ...] = ()
    slow: Tuple[Tuple[int, Tuple[int, ...]], ...] = ()
    slow_s: float = 0.0
    host_stragglers: Tuple[Tuple[int, Tuple[int, ...]], ...] = ()
    hedge_stragglers: bool = False
    shard_deadline_s: Optional[float] = None
    # token-level continuous batching: fuse through the engine's
    # persistent stream fuser, pushing per-decode-step StreamEvents into
    # every future (final responses and the event trace stay byte-equal
    # to the batch-boundary path — pinned by the streaming test tier)
    streaming: bool = False
    stream_capacity: Optional[int] = None
    prefill_chunk: Optional[int] = None


def build_arrivals(scenario: Scenario,
                   records: Sequence[Record]) -> List[Tuple[int, EnsembleRequest]]:
    """The scenario's deterministic arrival schedule: (tick, request) pairs,
    non-decreasing in tick.  Records cycle in order, so request *i* always
    carries ``records[i % len(records)]`` — the offline-equivalence tests
    rely on this mapping."""
    if not records:
        raise ValueError("need at least one record to build traffic from")
    rng = np.random.default_rng(scenario.seed)
    ticks = scenario.arrivals.arrival_ticks(scenario.n_requests, rng)
    weights = np.asarray([w for w, _ in scenario.mix], np.float64)
    if scenario.mix:
        weights = weights / weights.sum()
    out = []
    for i, tick in enumerate(ticks):
        overrides: Dict[str, Any] = {}
        if scenario.mix:
            overrides = dict(scenario.mix[int(rng.choice(len(scenario.mix),
                                                         p=weights))][1])
        if "deadline_ticks" not in overrides and scenario.deadline_ticks is not None:
            overrides["deadline_ticks"] = scenario.deadline_ticks
        rec = records[i % len(records)]
        out.append((tick, EnsembleRequest(query=rec.query, record=rec, **overrides)))
    return out


@dataclasses.dataclass(frozen=True)
class CapturedTrace:
    """A replayable arrival capture: the requests of one run plus, per
    request, the logical tick and the monotonic wall-clock nanosecond at
    which it was submitted.  This is the artifact a production deployment
    persists so new builds can be driven by real traffic."""

    name: str
    requests: Tuple[EnsembleRequest, ...]
    ticks: Tuple[int, ...]
    wall_ns: Tuple[int, ...]

    def ns_per_tick(self) -> float:
        """The capture's own wall-time calibration of one logical tick
        (0.0 when the capture spans less than one tick or one ns)."""
        if len(self.ticks) < 2:
            return 0.0
        span_ticks = self.ticks[-1] - self.ticks[0]
        span_ns = self.wall_ns[-1] - self.wall_ns[0]
        if span_ticks <= 0 or span_ns <= 0:
            return 0.0
        return span_ns / span_ticks


@dataclasses.dataclass
class TrafficReport:
    """What one simulated run produced, in arrival order."""

    scenario: str
    requests: List[EnsembleRequest]
    responses: List[Optional[EnsembleResponse]]  # None where shed/failed
    errors: List[Optional[BaseException]]
    latency_ticks: List[Optional[int]]  # dispatch tick - arrival tick
    wall_latency_s: List[Optional[float]]
    deadline_missed: List[bool]
    trace: List[dict]  # the scheduler's deterministic event log
    stats: Dict[str, int]  # scheduler counters at end of run
    compiles: Dict[str, int]  # engine generate-compile counters
    ticks: int  # total scheduler ticks consumed
    arrival_ticks: List[int] = dataclasses.field(default_factory=list)
    arrival_wall_ns: List[int] = dataclasses.field(default_factory=list)

    # -- summary metrics -------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.requests)

    @property
    def served(self) -> int:
        return sum(r is not None for r in self.responses)

    @property
    def shed_rate(self) -> float:
        return self.stats.get("shed", 0) / max(self.n, 1)

    @property
    def deadline_miss_rate(self) -> float:
        return sum(self.deadline_missed) / max(self.n, 1)

    def latency_percentiles(self, qs=(50, 99)) -> Dict[str, float]:
        """p50/p99 (by default) over wall-clock and tick latencies of the
        served requests."""
        walls = [w for w in self.wall_latency_s if w is not None]
        ticks = [t for t in self.latency_ticks if t is not None]
        out: Dict[str, float] = {}
        for q in qs:
            out[f"p{q}_latency_s"] = float(np.percentile(walls, q)) if walls else 0.0
            out[f"p{q}_latency_ticks"] = (
                float(np.percentile(ticks, q)) if ticks else 0.0)
        return out

    def captured(self) -> CapturedTrace:
        """The run's arrival schedule as a replayable capture."""
        return CapturedTrace(
            name=self.scenario,
            requests=tuple(self.requests),
            ticks=tuple(self.arrival_ticks),
            wall_ns=tuple(self.arrival_wall_ns),
        )


class TrafficSimulator:
    """Drives a Scheduler through one Scenario, tick by tick."""

    def __init__(self, scheduler: Scheduler, scenario: Scenario,
                 records: Sequence[Record]):
        self.scheduler = scheduler
        self.scenario = scenario
        self.records = list(records)
        cluster_wired = (scenario.host_failures or scenario.hosts
                         or scenario.host_recoveries or scenario.fanout
                         or scenario.probe_interval
                         or scenario.host_stragglers)
        if scenario.failures or scenario.slow or cluster_wired:
            # always wrap fresh around the innermost backend: a reused
            # server keeps neither a previous scenario's schedules nor its
            # consumed call/dispatch counters nor its dead hosts, so
            # replay() stays byte-identical
            backend = scheduler.server.backend
            while isinstance(backend, (FailureInjector, ClusterRouter)):
                if isinstance(backend, ClusterRouter):
                    backend.close()  # stop a stale router's executor threads
                backend = backend.inner
            if scenario.failures or scenario.slow:
                backend = FailureInjector(
                    backend, failures={m: tuple(calls)
                                       for m, calls in scenario.failures},
                    slow={m: tuple(calls) for m, calls in scenario.slow},
                    slow_s=scenario.slow_s)
            if cluster_wired:
                plan = PlacementPlan.auto(scheduler.server.pool,
                                          n_hosts=scenario.hosts or DEFAULT_HOSTS,
                                          replicas=scenario.replicas)
                recovery = {h: tuple(ticks)
                            for h, ticks in scenario.host_recoveries}
                health = None
                if scenario.probe_interval is not None:
                    # probe-driven health replaces schedule-driven
                    # revival outright: the recovery ticks feed the
                    # monitor (when each host's underlying health
                    # returns), and the router gets no host_recovery
                    # schedule of its own
                    health = HealthMonitor(
                        plan,
                        probe_interval=scenario.probe_interval,
                        probe_failures=scenario.probe_failures,
                        probe_faults={h: tuple(ks)
                                      for h, ks in scenario.probe_faults},
                        recovery=recovery)
                    recovery = {}
                backend = ClusterRouter(
                    backend, plan=plan,
                    host_failures={h: tuple(calls)
                                   for h, calls in scenario.host_failures},
                    host_recovery=recovery,
                    probation_ticks=scenario.probation_ticks,
                    rebalance=scenario.rebalance,
                    fanout=scenario.fanout,
                    health=health,
                    host_stragglers={h: tuple(ks) for h, ks
                                     in scenario.host_stragglers},
                    hedge_stragglers=scenario.hedge_stragglers,
                    shard_deadline_s=scenario.shard_deadline_s)
            scheduler.server.backend = backend
        if scenario.streaming:
            scheduler.enable_streaming(capacity=scenario.stream_capacity,
                                       prefill_chunk=scenario.prefill_chunk)

    def run(self, max_idle_ticks: int = 1000) -> TrafficReport:
        arrivals = build_arrivals(self.scenario, self.records)
        return self._drive(arrivals, self.scenario.name, max_idle_ticks)

    @classmethod
    def replay(cls, scheduler: Scheduler, trace: CapturedTrace,
               time_scale: float = 1.0,
               max_idle_ticks: int = 1000) -> TrafficReport:
        """Re-drive a captured arrival schedule against a (new) scheduler.

        ``time_scale == 1.0`` replays the recorded *logical* ticks
        verbatim — the byte-identical re-drive the determinism tests pin.
        Any other scale switches to the recorded wall clock: each
        request's arrival tick is derived from its captured wall-clock
        offset via the capture's own ns-per-tick calibration, divided by
        ``time_scale`` (2.0 = twice as fast, 0.5 = half speed) — so a
        production capture replays with its real arrival spacing, not the
        simulator's idealized one."""
        if time_scale <= 0:
            raise ValueError("time_scale must be > 0")
        ns_per_tick = trace.ns_per_tick()
        if time_scale == 1.0 or ns_per_tick == 0.0:
            ticks = [int(round(t / time_scale)) for t in trace.ticks]
        else:
            t0 = trace.wall_ns[0]
            ticks = [int((w - t0) / ns_per_tick / time_scale)
                     for w in trace.wall_ns]
        sim = cls(scheduler, Scenario(name=f"{trace.name}@x{time_scale:g}"), [])
        arrivals = list(zip(ticks, trace.requests))
        return sim._drive(arrivals, sim.scenario.name, max_idle_ticks)

    def _drive(self, arrivals: List[Tuple[int, EnsembleRequest]], name: str,
               max_idle_ticks: int = 1000) -> TrafficReport:
        """Submit the arrival schedule against the scheduler's clock and
        tick until every future resolves.  Engine-side batch failures are
        recorded per request (futures are always resolved), never raised —
        a scenario run always completes."""
        sched = self.scheduler
        futures: List = []
        submit_s: List[float] = []
        wall_ns: List[int] = []
        done_s: List[Optional[float]] = []
        requests = [req for _, req in arrivals]

        def stamp():
            t = time.perf_counter()
            for i, f in enumerate(futures):
                if f.done() and done_s[i] is None:
                    done_s[i] = t

        idx = 0
        idle = 0
        while idx < len(arrivals) or sched.pending:
            while idx < len(arrivals) and arrivals[idx][0] <= sched.now:
                submit_s.append(time.perf_counter())
                wall_ns.append(time.perf_counter_ns())
                done_s.append(None)
                try:
                    futures.append(sched.submit(arrivals[idx][1]))
                except Exception:
                    # an inline dispatch crashed past hedging: the batch's
                    # futures (possibly including ours) are resolved with
                    # the cause; recover the handle so the report still
                    # accounts for this request
                    if sched.last_submitted is None:
                        raise  # validation error — a sim bug, surface it
                    futures.append(sched.last_submitted)
                idx += 1
                stamp()
            before = sched.pending
            try:
                sched.tick()
            except Exception:
                pass  # batch futures already resolved with the cause
            stamp()
            idle = idle + 1 if sched.pending == before and idx >= len(arrivals) else 0
            if idle > max_idle_ticks:
                raise RuntimeError(
                    f"simulator failed to drain: {sched.pending} requests "
                    f"still pending after {max_idle_ticks} idle ticks")
        sched.join()  # async mode: wait out in-flight batches
        stamp()

        latency_ticks: List[Optional[int]] = [None] * len(futures)
        missed = [False] * len(futures)
        seq_to_i = {f.seq: i for i, f in enumerate(futures)}
        for ev in sched.events:
            if ev["event"] == "complete" and ev["req"] in seq_to_i:
                i = seq_to_i[ev["req"]]
                latency_ticks[i] = ev["latency_ticks"]
                missed[i] = ev["missed"]
        responses: List[Optional[EnsembleResponse]] = []
        errors: List[Optional[BaseException]] = []
        walls: List[Optional[float]] = []
        for i, f in enumerate(futures):
            err = f._error
            responses.append(f._response if err is None else None)
            errors.append(err)
            walls.append(done_s[i] - submit_s[i]
                         if err is None and done_s[i] is not None else None)
        return TrafficReport(
            scenario=name,
            requests=requests,
            responses=responses,
            errors=errors,
            latency_ticks=latency_ticks,
            wall_latency_s=walls,
            deadline_missed=missed,
            trace=list(sched.events),
            stats=dict(sched.stats),
            compiles=sched.server.generate_compiles(),
            ticks=sched.now,
            arrival_ticks=[t for t, _ in arrivals],
            arrival_wall_ns=wall_ns,
        )


def replay(scheduler_factory, scenario: Scenario,
           records: Sequence[Record]) -> TrafficReport:
    """Re-run a scenario from scratch on a fresh scheduler.  Because every
    source of variation is seed-keyed, the returned report's trace is
    byte-identical to the original run's."""
    return TrafficSimulator(scheduler_factory(), scenario, records).run()


def preset_scenarios(n_requests: int = 24, seed: int = 0) -> Dict[str, Scenario]:
    """The named scenarios the benchmarks and the scenario test suite
    share.  ``failure`` injects a transient fault on member 3 (one of the
    two members modi@0.2 reliably selects under the default stack seeds),
    so hedged retry actually fires; ``host-outage`` kills a whole
    placement host mid-run, so the host-level hedge (knapsack re-solve
    over the survivors) fires; ``host-recovery`` additionally declares
    the dead host healthy at tick 4 and re-admits it after a 1-tick
    probation window, so late batches select the revived host's members
    again (outage → probation → revival); every future still resolves.

    ``probe-recovery`` is the probe-driven counterpart of
    ``host-recovery``: the same outage and the same underlying-health
    return tick, but revival happens through the HealthMonitor's
    half-open probe at the next probe tick — observed liveness, which
    beats the schedule+probation path's revival tick.  ``grey-failure``
    exercises the grey modes together: host 0's dispatches 1–2 straggle
    and are hedged onto a replica at consume time, while a flaky probe
    on host 2 fails once (below the breaker threshold — trace-visible,
    no death)."""
    return {
        "steady": Scenario(
            name="steady",
            arrivals=ArrivalProcess("steady", rate=2.0),
            n_requests=n_requests, seed=seed, deadline_ticks=4,
        ),
        "bursty": Scenario(
            name="bursty",
            arrivals=ArrivalProcess("bursty", burst_size=8, burst_every=6),
            n_requests=n_requests, seed=seed, deadline_ticks=3,
            mix=(
                (0.7, {}),
                (0.2, {"budget": 0.5, "priority": 1}),
                (0.1, {"policy": "best-single", "priority": 2,
                       "deadline_ticks": 1}),
            ),
        ),
        "heavy-tail": Scenario(
            name="heavy-tail",
            arrivals=ArrivalProcess("heavy-tail", tail_shape=1.1),
            n_requests=n_requests, seed=seed, deadline_ticks=6,
            mix=(
                (0.6, {}),
                (0.3, {"budget": 0.6}),
                (0.1, {"policy": "llm-blender", "priority": 3}),
            ),
        ),
        "failure": Scenario(
            name="failure",
            arrivals=ArrivalProcess("steady", rate=2.0),
            n_requests=n_requests, seed=seed, deadline_ticks=4,
            failures=((3, (1,)),),
        ),
        "diurnal": Scenario(
            name="diurnal",
            arrivals=ArrivalProcess("diurnal", rate=2.0, period=12,
                                    amplitude=0.9),
            n_requests=n_requests, seed=seed, deadline_ticks=2,
            mix=(
                (0.8, {}),
                (0.2, {"budget": 0.5, "priority": 1}),
            ),
        ),
        "host-outage": Scenario(
            name="host-outage",
            arrivals=ArrivalProcess("steady", rate=2.0),
            n_requests=n_requests, seed=seed, deadline_ticks=4,
            hosts=4, host_failures=((0, (1,)),),
        ),
        "host-recovery": Scenario(
            name="host-recovery",
            arrivals=ArrivalProcess("steady", rate=2.0),
            n_requests=n_requests, seed=seed, deadline_ticks=4,
            hosts=4, host_failures=((0, (1,)),),
            host_recoveries=((0, (4,)),), probation_ticks=1,
        ),
        "probe-recovery": Scenario(
            name="probe-recovery",
            arrivals=ArrivalProcess("steady", rate=2.0),
            n_requests=n_requests, seed=seed, deadline_ticks=4,
            hosts=4, host_failures=((0, (1,)),),
            host_recoveries=((0, (4,)),),
            probe_interval=2, probe_failures=1,
        ),
        "grey-failure": Scenario(
            name="grey-failure",
            arrivals=ArrivalProcess("steady", rate=2.0),
            n_requests=n_requests, seed=seed, deadline_ticks=4,
            hosts=4, replicas=2,
            host_stragglers=((0, (1, 2)),), hedge_stragglers=True,
            probe_interval=3, probe_failures=2,
            probe_faults=((2, (1,)),),
        ),
        "streaming": Scenario(
            name="streaming",
            arrivals=ArrivalProcess("steady", rate=2.0),
            n_requests=n_requests, seed=seed, deadline_ticks=4,
            streaming=True, stream_capacity=8,
            mix=(
                (0.7, {}),
                (0.2, {"max_new_tokens": 12}),
                (0.1, {"max_new_tokens": 48, "priority": 1}),
            ),
        ),
    }
