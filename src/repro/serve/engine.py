"""MODI ensemble serving engine (paper §2.3 end-to-end).

The engine is the composition point of four layers, each replaceable on
its own:

* request surface — :class:`repro.serve.api.EnsembleRequest` /
  :class:`EnsembleResponse` (per-request budget, policy, generation length);
* selection — any :class:`repro.core.SelectionPolicy`, constructed by
  name through :func:`repro.core.make_policy`, resolved **per request**
  and grouped so each distinct (policy, budget) runs one vectorized
  ``select`` over its rows;
* member generation — a :class:`repro.serve.backends.MemberBackend`
  (behavioural simulator or live JAX LMs), batched per member over the
  rows that selected it;
* fusion — GEN-FUSER greedy decoding over the selected responses.

Pipeline per admission micro-batch:
    1. predictor scores the query for every pool member  (r_hat [B, N])
    2. Kaplan costs c_i · t_i(q) per member              (costs [B, N])
    3. per-request policy (MODI = ε-constrained knapsack) (mask [B, N])
    4. backend generates for the selected members
    5. GEN-FUSER fuses the selected responses into the final answer
    6. cost accounting: realized FLOPs vs the full-ensemble (LLM-BLENDER)

``serve(records)`` is the offline batch entry point (Table-1 benchmark);
``serve_requests(requests)`` is the request-level path the
:class:`repro.serve.scheduler.Scheduler` drives for online traffic.
Both produce identical outputs for identical inputs.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.epsilon import EpsilonConstraint
from repro.core.fusion import build_fusion_batch
from repro.core.predictor import QualityPredictor
from repro.core.selector import SelectionPolicy, make_policy, realized_cost_fraction
from repro.data.mixinstruct import PoolMemberSpec, Record, query_cost_matrix
from repro.data.tokenizer import TOKENIZER
from repro.models.encdec import EncDecLM
from repro.serve.api import EnsembleRequest, EnsembleResponse, requests_from_records
from repro.serve.backends import (
    GenerationCall,
    HostFailure,
    LiveLMBackend,
    LiveMember,
    MemberBackend,
    MemberFailure,
    SimBackend,
)
from repro.serve.dispatch import (
    BucketLadder,
    EncDecGenerateDispatcher,
    StreamingEncDecBatcher,
)
from repro.serve.generate import greedy_generate_encdec


@dataclasses.dataclass
class _BatchPlan:
    """Everything ``serve_requests`` computes before fusion, so the batch
    and streaming paths share one pre-fusion pipeline (predict → select →
    member generation) and one settlement path, and can only diverge in
    *how* fusion tokens are produced — never in what they are."""

    records: List[Record]
    queries: List[str]
    r_hat: np.ndarray  # [B, N]
    costs: np.ndarray  # [B, N]
    mask: np.ndarray  # [B, N]
    policy_names: List[str]
    dropped: frozenset
    max_new_per_row: List[int]
    member_out: List[List[Optional[str]]]
    predict_s: float
    select_s: float
    generate_s: float


@dataclasses.dataclass
class ServeResult:
    """Batch-level view of a served record list (offline evaluation)."""

    responses: List[str]
    mask: np.ndarray  # [B, N] selections
    cost_fraction: np.ndarray  # [B] realized / full-ensemble cost
    member_responses: List[List[Optional[str]]]  # [B][N] (None if unselected)
    predicted_quality: np.ndarray  # [B, N]


class EnsembleServer:
    def __init__(
        self,
        pool: Sequence[PoolMemberSpec],
        policy: SelectionPolicy,
        predictor: QualityPredictor,
        predictor_params: dict,
        fuser: EncDecLM,
        fuser_params: dict,
        live_members: Optional[Sequence[LiveMember]] = None,
        backend: Optional[MemberBackend] = None,
        max_query_len: int = 96,
        max_fusion_len: int = 512,
        max_new_tokens: int = 32,
        max_member_tokens: Optional[int] = None,
        sim_seed: int = 0,
        fast_generate: bool = True,
        bucket_ladder: Optional[BucketLadder] = None,
        warm_shapes: Optional[Sequence[Tuple[int, int]]] = None,
    ):
        self.pool = list(pool)
        self.policy = policy
        self.predictor = predictor
        self.predictor_params = predictor_params
        self.fuser = fuser
        self.fuser_params = fuser_params
        ladder = bucket_ladder or BucketLadder()
        # the Scheduler reads this to target batch sizes that land on
        # already-compiled rungs (continuous batch formation)
        self.bucket_ladder = ladder
        if backend is None:
            if live_members is not None:
                backend = LiveLMBackend(list(live_members), max_query_len=max_query_len,
                                        fast=fast_generate, ladder=ladder)
            else:
                backend = SimBackend(self.pool, seed=sim_seed)
        if backend.num_members() != len(self.pool):
            raise ValueError(
                f"backend serves {backend.num_members()} members but the pool "
                f"has {len(self.pool)}"
            )
        self.backend = backend
        self.max_query_len = max_query_len
        self.max_fusion_len = max_fusion_len
        self.max_new_tokens = max_new_tokens
        # cap on member-response tokens entering fusion; None = never truncate
        # below a row's own max_new cap (the old behaviour hardcoded 64)
        self.max_member_tokens = max_member_tokens
        self.fuser_dispatch: Optional[EncDecGenerateDispatcher] = (
            EncDecGenerateDispatcher(fuser, fuser_params, ladder=ladder)
            if fast_generate else None
        )
        # lazily-built continuous-batching fuser for the streaming path
        self._stream_fuser: Optional[StreamingEncDecBatcher] = None
        if warm_shapes:
            self.warm(warm_shapes)
        self.stats: Dict[str, float] = {
            "queries": 0, "batches": 0, "flops": 0.0, "full_flops": 0.0,
        }

    # ------------------------------------------------------------------
    def warm(self, shapes: Sequence[Tuple[int, int]]) -> None:
        """Pre-compile generate buckets for (batch, max_new) shapes so the
        first admission micro-batches don't pay the compile.  Backends
        opt in by exposing ``warm(shapes)`` (optional protocol hook — see
        LiveLMBackend); backends without one have nothing to compile."""
        if self.fuser_dispatch is not None:
            self.fuser_dispatch.warm(
                [(b, self.max_fusion_len, n) for b, n in shapes]
            )
        backend_warm = getattr(self.backend, "warm", None)
        if callable(backend_warm):
            backend_warm(shapes)

    def generate_compiles(self) -> Dict[str, int]:
        """Live XLA compile counts on the generate fast paths (0 when the
        corresponding path is disabled or has not run).  Backends report
        theirs through an optional ``compiles()`` hook."""
        fuser = self.fuser_dispatch.compiles if self.fuser_dispatch else 0
        backend_compiles = getattr(self.backend, "compiles", None)
        members = backend_compiles() if callable(backend_compiles) else 0
        stream = self._stream_fuser.compiles if self._stream_fuser else 0
        return {"fuser": fuser, "members": members, "stream": stream,
                "total": fuser + members + stream}

    # ------------------------------------------------------------------
    def predict_quality(self, queries: List[str]) -> np.ndarray:
        toks = TOKENIZER.batch_encode(queries, self.max_query_len, cls=True)
        return np.asarray(self.predictor.apply(self.predictor_params, jnp.asarray(toks)))

    # ------------------------------------------------------------------
    def _policy_key(self, req: EnsembleRequest) -> Tuple:
        """Hashable group key that fully determines the resolved policy.

        A request naming a policy gets a fresh registry construction; a
        request overriding only the budget (or other fields) keeps every
        other knob of the server's configured policy instance."""
        if req.policy is not None:
            kwargs = dict(req.policy_kwargs or {})
            if req.budget is not None:
                kwargs["budget"] = req.budget
            return (req.policy, tuple(sorted(kwargs.items())))
        changes = dict(req.policy_kwargs or {})
        if req.budget is not None:
            eps = getattr(self.policy, "eps", None)
            if isinstance(eps, EpsilonConstraint):
                changes["eps"] = EpsilonConstraint(req.budget, eps.buckets)
            # budget-insensitive default policy: the override is a no-op
        if not changes:
            return ("__default__",)
        return ("__default__", tuple(sorted(changes.items())))

    def _build_policy(self, key: Tuple) -> SelectionPolicy:
        """Construct the policy a :meth:`_policy_key` describes (once per group)."""
        if key == ("__default__",):
            return self.policy
        name, items = key
        if name == "__default__":
            return dataclasses.replace(self.policy, **dict(items))
        return make_policy(name, **dict(items))

    def _select(self, requests: List[EnsembleRequest], r_hat: np.ndarray,
                costs: np.ndarray,
                masked_members: frozenset = frozenset(),
                ) -> Tuple[np.ndarray, List[str]]:
        """[B, N] mask + per-request policy name, grouping rows that share a
        resolved policy so each policy is built and vector-selected once.

        ``masked_members`` (dead hosts' members) re-solves budget-aware
        policies over the surviving columns only: the knapsack sees the
        survivors' costs and an ε budget over the survivors' full-ensemble
        cost, instead of wasting budget headroom on members that cannot
        serve.  Policies without an ε constraint (and index-keyed
        baselines, whose indices address the full pool) run on the full
        matrix; the caller's exclusion guard strips dead members from
        their masks afterwards."""
        b, n = r_hat.shape
        groups: Dict[Tuple, Tuple[SelectionPolicy, List[int]]] = {}
        for i, req in enumerate(requests):
            key = self._policy_key(req)
            if key not in groups:
                groups[key] = (self._build_policy(key), [])
            groups[key][1].append(i)
        mask = np.zeros((b, n), bool)
        names = [""] * b
        alive = np.asarray([j for j in range(n) if j not in masked_members],
                           dtype=np.intp)
        for policy, rows in groups.values():
            resolve_masked = (
                bool(masked_members)
                and isinstance(getattr(policy, "eps", None), EpsilonConstraint)
            )
            if resolve_masked:
                picked = np.asarray(policy.select(
                    jnp.asarray(r_hat[rows][:, alive]),
                    jnp.asarray(costs[rows][:, alive]),
                ))
                sub = np.zeros((len(rows), n), bool)
                sub[:, alive] = picked
            else:
                sub = np.asarray(
                    policy.select(jnp.asarray(r_hat[rows]), jnp.asarray(costs[rows]))
                )
            for local, i in enumerate(rows):
                mask[i] = sub[local]
                names[i] = policy.name
        return mask, names

    # ------------------------------------------------------------------
    def _generate_members(self, records: List[Record], mask: np.ndarray,
                          max_new_per_row: List[int]) -> List[List[Optional[str]]]:
        """[B][N] texts, batched per member over its selected rows.

        Per-row token caps travel to the backend, which owns truncation
        (see backends.MemberBackend): each returned text is already at
        most its row's cap, so no re-tokenization happens here.  Caps are
        per row, never per micro-batch, so texts cannot depend on which
        other rows share the batch.

        A backend exposing ``generate_many(calls)`` (optional protocol
        hook — the cluster router's fan-out seam) receives the whole
        batch's calls at once so per-host shards can generate
        concurrently; it owns the same failure attribution this loop
        applies, and its results are order- and byte-identical to the
        sequential path."""
        b, n = mask.shape
        out: List[List[Optional[str]]] = [[None] * n for _ in range(b)]
        calls: List[GenerationCall] = []
        call_rows: List[np.ndarray] = []
        for j in range(n):
            rows = np.flatnonzero(mask[:, j])
            if rows.size == 0:
                continue
            calls.append(GenerationCall(
                j, tuple(records[i] for i in rows),
                tuple(max_new_per_row[i] for i in rows)))
            call_rows.append(rows)
        many = getattr(self.backend, "generate_many", None)
        if callable(many):
            texts_per_call = many(calls)
        else:
            texts_per_call = []
            for call in calls:
                try:
                    texts_per_call.append(self.backend.generate(
                        call.member_idx, list(call.records),
                        list(call.max_new_tokens)))
                except (MemberFailure, HostFailure):
                    # already attributed (member-level, or a whole placement
                    # host via the cluster router) — let the Scheduler hedge
                    raise
                except Exception as exc:
                    # attribute the fault to the member so the Scheduler can
                    # hedge onto the survivors instead of failing the batch
                    raise MemberFailure(call.member_idx, exc) from exc
        for call, rows, texts in zip(calls, call_rows, texts_per_call):
            for i, text in zip(rows, texts):
                out[i][call.member_idx] = text
        return out

    def _apply_exclusions(self, mask: np.ndarray, costs: np.ndarray,
                          exclude_members: frozenset) -> np.ndarray:
        """Zero excluded members out of the selection; rows left empty fall
        back to the cheapest *surviving* member so every query still gets
        an answer (the same guard ModiPolicy applies for an over-tight ε).
        Used by the Scheduler's hedged retry after a MemberFailure."""
        excl = sorted(exclude_members)
        if not excl:
            return mask
        n = mask.shape[1]
        if not all(0 <= j < n for j in excl):
            raise ValueError(f"exclude_members {excl} out of range for pool of {n}")
        if len(excl) >= n:
            raise ValueError("cannot exclude every pool member")
        mask = mask.copy()
        mask[:, excl] = False
        empty = ~mask.any(axis=1)
        if empty.any():
            alive_costs = costs.copy()
            alive_costs[:, excl] = np.inf
            cheapest = np.argmin(alive_costs, axis=1)
            mask[np.flatnonzero(empty), cheapest[empty]] = True
        return mask

    def _fusion_inputs(self, queries: List[str],
                       member_out: List[List[Optional[str]]],
                       mask: np.ndarray, max_new: int) -> np.ndarray:
        """Encoder tokens [B, max_fusion_len] for the GEN-FUSER — shared by
        the batch-boundary and streaming fusion paths, so both decode the
        very same prompt."""
        b, n = mask.shape
        # member texts are pre-truncated to their row's max_new cap; the
        # fusion-side cap only narrows further if explicitly configured
        cap = max_new if self.max_member_tokens is None else self.max_member_tokens
        flat = [
            (i, j, text)
            for i, row in enumerate(member_out)
            for j, text in enumerate(row)
            if text is not None
        ]
        resp_tokens = np.full((b, n, cap), TOKENIZER.pad_id, np.int32)
        if flat:
            # one batched tokenizer call over flat index arrays instead of a
            # [B, N] Python grid of encode+assign steps
            ii = np.fromiter((f[0] for f in flat), np.intp, len(flat))
            jj = np.fromiter((f[1] for f in flat), np.intp, len(flat))
            resp_tokens[ii, jj] = TOKENIZER.pad_batch(
                [TOKENIZER.encode(f[2]) for f in flat], cap
            )
        q_tokens = TOKENIZER.batch_encode(queries, self.max_query_len)
        return build_fusion_batch(
            q_tokens, resp_tokens, mask, TOKENIZER.sep_id, self.max_fusion_len,
            TOKENIZER.pad_id,
        )

    def _fuse(self, queries: List[str], member_out: List[List[Optional[str]]],
              mask: np.ndarray, max_new: int) -> np.ndarray:
        fuse_in = self._fusion_inputs(queries, member_out, mask, max_new)
        if self.fuser_dispatch is not None:
            return self.fuser_dispatch(fuse_in, max_new)
        return greedy_generate_encdec(
            self.fuser, self.fuser_params, fuse_in, max_new=max_new
        )

    # ------------------------------------------------------------------
    def serve_requests(
        self,
        requests: List[EnsembleRequest],
        exclude_members: frozenset = frozenset(),
        masked_members: frozenset = frozenset(),
    ) -> List[EnsembleResponse]:
        """Serve one admission micro-batch of requests (the Scheduler's path).

        ``exclude_members`` drops those pool members from every request's
        selection *after* the policy runs (hedged retry around a down
        member); requests whose selection never touched the excluded
        members produce byte-identical responses with or without the
        exclusion.  ``masked_members`` (members dead with their placement
        host — see :class:`~repro.serve.backends.HostFailure`) goes
        further: budget-aware policies re-solve their knapsack over the
        surviving members only, so the ε budget re-targets the survivors'
        full-ensemble cost instead of carrying dead members' costs."""
        if not requests:
            return []
        t_start = time.perf_counter()
        plan = self._plan_batch(requests, exclude_members, masked_members)

        max_new = max(plan.max_new_per_row)
        t0 = time.perf_counter()
        fused = self._fuse(plan.queries, plan.member_out, plan.mask, max_new)
        t_fuse = time.perf_counter() - t0

        row_tokens = [fused[i, :plan.max_new_per_row[i]]
                      for i in range(len(requests))]
        return self._settle(plan, row_tokens, t_start, t_fuse)

    def _plan_batch(self, requests: List[EnsembleRequest],
                    exclude_members: frozenset,
                    masked_members: frozenset) -> _BatchPlan:
        """Pre-fusion pipeline (predict → select → member generation),
        shared verbatim by the batch-boundary and streaming paths."""
        records = [req.resolve_record() for req in requests]
        queries = [r.query for r in records]

        t0 = time.perf_counter()
        r_hat = self.predict_quality(queries)
        t_predict = time.perf_counter() - t0

        costs = query_cost_matrix(self.pool, records)
        t0 = time.perf_counter()
        masked = frozenset(masked_members)
        mask, policy_names = self._select(requests, r_hat, costs,
                                          masked_members=masked)
        dropped = frozenset(exclude_members) | masked
        if dropped:
            mask = self._apply_exclusions(mask, costs, dropped)
        t_select = time.perf_counter() - t0

        max_new_per_row = [
            self.max_new_tokens if req.max_new_tokens is None else req.max_new_tokens
            for req in requests
        ]
        t0 = time.perf_counter()
        member_out = self._generate_members(records, mask, max_new_per_row)
        t_generate = time.perf_counter() - t0
        return _BatchPlan(
            records=records, queries=queries, r_hat=r_hat, costs=costs,
            mask=mask, policy_names=policy_names, dropped=dropped,
            max_new_per_row=max_new_per_row, member_out=member_out,
            predict_s=t_predict, select_s=t_select, generate_s=t_generate,
        )

    def _settle(self, plan: _BatchPlan, row_tokens: Sequence,
                t_start: float, t_fuse: float) -> List[EnsembleResponse]:
        """Cost accounting + response assembly over per-row fused tokens
        (a ``[row_new]`` slice from the batch path, or the exact emitted
        sequence from the streaming path — both decode to the same text)."""
        mask, costs, dropped = plan.mask, plan.costs, plan.dropped
        frac = np.asarray(realized_cost_fraction(jnp.asarray(mask), jnp.asarray(costs)))
        realized = np.sum(np.where(mask, costs, 0.0), axis=1)
        # full-ensemble cost over the servable members only — the base a
        # degraded batch settles against (ε re-targeted the survivors)
        servable = np.asarray([j not in dropped for j in range(costs.shape[1])])
        survivor_cost = np.sum(np.where(servable, costs, 0.0), axis=1)
        total = time.perf_counter() - t_start
        timing = {
            "predict_s": plan.predict_s, "select_s": plan.select_s,
            "generate_s": plan.generate_s, "fuse_s": t_fuse, "total_s": total,
        }

        self.stats["queries"] += len(plan.records)
        self.stats["batches"] += 1
        self.stats["flops"] += float(realized.sum())
        self.stats["full_flops"] += float(np.sum(costs))

        responses = []
        for i in range(len(plan.records)):
            responses.append(EnsembleResponse(
                text=TOKENIZER.decode(row_tokens[i]),
                member_texts=plan.member_out[i],
                mask=mask[i],
                realized_cost=float(realized[i]),
                cost_fraction=float(frac[i]),
                predicted_quality=plan.r_hat[i],
                policy_name=plan.policy_names[i],
                timing=dict(timing),
                degraded=bool(dropped),
                missing_members=tuple(sorted(dropped)),
                survivor_cost=float(survivor_cost[i]),
            ))
        return responses

    # ------------------------------------------------------------------
    def stream_fuser(self, capacity: int = 8,
                     prefill_chunk: Optional[int] = None,
                     ) -> StreamingEncDecBatcher:
        """The continuous-batching fuser, built on first use.  ``capacity``
        and ``prefill_chunk`` only apply to that first construction — the
        in-flight state is persistent, so later callers share it."""
        if self._stream_fuser is None:
            self._stream_fuser = StreamingEncDecBatcher(
                self.fuser, self.fuser_params, enc_seq=self.max_fusion_len,
                capacity=capacity, ladder=self.bucket_ladder,
                prefill_chunk=prefill_chunk,
            )
        return self._stream_fuser

    def serve_requests_stream(
        self,
        requests: List[EnsembleRequest],
        on_token=None,
        exclude_members: frozenset = frozenset(),
        masked_members: frozenset = frozenset(),
        capacity: int = 8,
        prefill_chunk: Optional[int] = None,
    ) -> List[EnsembleResponse]:
        """:meth:`serve_requests` with token-level continuous fusion: the
        GEN-FUSER decodes through the persistent :meth:`stream_fuser`
        batch, firing ``on_token(i, tokens_so_far)`` after every decode
        step of row ``i``.  Final responses are byte-identical to
        :meth:`serve_requests` — fusion prompts come from the same
        :meth:`_fusion_inputs`, the step body is the batch scan's body,
        and rows are independent, so co-residency (which rows share a
        decode step) cannot leak into any row's bytes.

        Rows whose cap exceeds the stream fuser's ``max_new_cap`` (or a
        server built with ``fast_generate=False``) fall back to the
        batch-boundary path for the whole micro-batch: ``on_token`` then
        fires once per row with the final tokens, so streaming consumers
        degrade to one coarse event rather than an error."""
        if not requests:
            return []
        t_start = time.perf_counter()
        plan = self._plan_batch(requests, exclude_members, masked_members)
        max_new = max(plan.max_new_per_row)

        fuser = (self.stream_fuser(capacity, prefill_chunk)
                 if self.fuser_dispatch is not None else None)
        if fuser is None or max_new > fuser.max_new_cap:
            t0 = time.perf_counter()
            fused = self._fuse(plan.queries, plan.member_out, plan.mask, max_new)
            t_fuse = time.perf_counter() - t0
            row_tokens = [fused[i, :plan.max_new_per_row[i]]
                          for i in range(len(requests))]
            if on_token is not None:
                for i, toks in enumerate(row_tokens):
                    on_token(i, [int(t) for t in toks])
            return self._settle(plan, row_tokens, t_start, t_fuse)

        t0 = time.perf_counter()
        fuse_in = self._fusion_inputs(plan.queries, plan.member_out,
                                      plan.mask, max_new)
        done_tokens: Dict[int, List[int]] = {}
        errors: List[BaseException] = []
        fuser.submit(
            fuse_in, list(plan.max_new_per_row),
            on_token=on_token,
            on_done=lambda i, toks: done_tokens.__setitem__(i, toks),
            on_error=lambda i, exc: errors.append(exc),
        )
        fuser.pump()
        if errors:
            raise errors[0]
        t_fuse = time.perf_counter() - t0
        row_tokens = [done_tokens[i] for i in range(len(requests))]
        return self._settle(plan, row_tokens, t_start, t_fuse)

    # ------------------------------------------------------------------
    def serve(self, records: List[Record],
              exclude_members: frozenset = frozenset()) -> ServeResult:
        """Offline batch entry point: one micro-batch over all records."""
        n = len(self.pool)
        out = self.serve_requests(requests_from_records(records),
                                  exclude_members=exclude_members)
        if not out:
            return ServeResult(
                responses=[],
                mask=np.zeros((0, n), bool),
                cost_fraction=np.zeros(0),
                member_responses=[],
                predicted_quality=np.zeros((0, n), np.float32),
            )
        return ServeResult(
            responses=[r.text for r in out],
            mask=np.stack([r.mask for r in out]),
            cost_fraction=np.asarray([r.cost_fraction for r in out]),
            member_responses=[r.member_texts for r in out],
            predicted_quality=np.stack([r.predicted_quality for r in out]),
        )
