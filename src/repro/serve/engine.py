"""MODI ensemble serving engine (paper §2.3 end-to-end).

Pipeline per batch of queries:
    1. predictor scores the query for every pool member  (r_hat [B, N])
    2. Kaplan costs c_i · t_i(q) per member              (costs [B, N])
    3. selection policy (MODI = ε-constrained knapsack)  (mask  [B, N])
    4. selected members generate responses — live tiny JAX LMs or the
       behavioral simulator (DESIGN.md §3)
    5. GEN-FUSER fuses the selected responses into the final answer
    6. cost accounting: realized FLOPs vs the full-ensemble (LLM-BLENDER)

The engine is policy-agnostic: every baseline in ``repro.core.selector``
plugs into the same pipeline, which is how the Table-1 benchmark runs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fusion import build_fusion_batch
from repro.core.predictor import QualityPredictor
from repro.core.selector import SelectionPolicy, realized_cost_fraction
from repro.data.mixinstruct import (
    PoolMemberSpec,
    Record,
    member_response,
    query_cost_matrix,
)
from repro.data.tokenizer import TOKENIZER
from repro.models.encdec import EncDecLM
from repro.models.transformer import DecoderLM
from repro.serve.generate import greedy_generate, greedy_generate_encdec


@dataclasses.dataclass
class LiveMember:
    spec: PoolMemberSpec
    model: DecoderLM
    params: dict


@dataclasses.dataclass
class ServeResult:
    responses: List[str]
    mask: np.ndarray  # [B, N] selections
    cost_fraction: np.ndarray  # [B] realized / full-ensemble cost
    member_responses: List[List[Optional[str]]]  # [B][N] (None if unselected)
    predicted_quality: np.ndarray  # [B, N]


class EnsembleServer:
    def __init__(
        self,
        pool: Sequence[PoolMemberSpec],
        policy: SelectionPolicy,
        predictor: QualityPredictor,
        predictor_params: dict,
        fuser: EncDecLM,
        fuser_params: dict,
        live_members: Optional[Sequence[LiveMember]] = None,
        max_query_len: int = 96,
        max_fusion_len: int = 512,
        max_new_tokens: int = 32,
        sim_seed: int = 0,
    ):
        self.pool = list(pool)
        self.policy = policy
        self.predictor = predictor
        self.predictor_params = predictor_params
        self.fuser = fuser
        self.fuser_params = fuser_params
        self.live_members = list(live_members) if live_members else None
        self.max_query_len = max_query_len
        self.max_fusion_len = max_fusion_len
        self.max_new_tokens = max_new_tokens
        self._sim_rng = np.random.default_rng(sim_seed)
        self.stats: Dict[str, float] = {"queries": 0, "flops": 0.0, "full_flops": 0.0}

    # ------------------------------------------------------------------
    def predict_quality(self, queries: List[str]) -> np.ndarray:
        toks = TOKENIZER.batch_encode(queries, self.max_query_len, cls=True)
        return np.asarray(self.predictor.apply(self.predictor_params, jnp.asarray(toks)))

    # ------------------------------------------------------------------
    def _generate_member(self, member_idx: int, queries: List[str], recs: List[Record]) -> List[str]:
        if self.live_members is None:
            spec = self.pool[member_idx]
            return [member_response(spec, r, self._sim_rng) for r in recs]
        lm = self.live_members[member_idx]
        prompts = [
            TOKENIZER.encode(q, bos=True) + [TOKENIZER.sep_id] for q in queries
        ]
        batch = TOKENIZER.pad_batch(prompts, self.max_query_len)
        out = greedy_generate(lm.model, lm.params, batch, max_new=self.max_new_tokens)
        return [TOKENIZER.decode(row) for row in out]

    # ------------------------------------------------------------------
    def serve(self, records: List[Record]) -> ServeResult:
        queries = [r.query for r in records]
        b, n = len(records), len(self.pool)
        r_hat = self.predict_quality(queries)
        costs = query_cost_matrix(self.pool, records)
        mask = np.asarray(self.policy.select(jnp.asarray(r_hat), jnp.asarray(costs)))

        # generate member responses (batched per member over its selected rows)
        member_out: List[List[Optional[str]]] = [[None] * n for _ in range(b)]
        for j in range(n):
            rows = [i for i in range(b) if mask[i, j]]
            if not rows:
                continue
            outs = self._generate_member(j, [queries[i] for i in rows], [records[i] for i in rows])
            for i, o in zip(rows, outs):
                member_out[i][j] = o

        # fuse
        resp_tokens = np.full((b, n, 64), TOKENIZER.pad_id, np.int32)
        for i in range(b):
            for j in range(n):
                if member_out[i][j] is not None:
                    enc = TOKENIZER.encode(member_out[i][j])[:64]
                    resp_tokens[i, j, : len(enc)] = enc
        q_tokens = TOKENIZER.batch_encode(queries, self.max_query_len)
        fuse_in = build_fusion_batch(
            q_tokens, resp_tokens, mask, TOKENIZER.sep_id, self.max_fusion_len, TOKENIZER.pad_id
        )
        fused = greedy_generate_encdec(
            self.fuser, self.fuser_params, fuse_in, max_new=self.max_new_tokens
        )
        responses = [TOKENIZER.decode(row) for row in fused]

        frac = np.asarray(realized_cost_fraction(jnp.asarray(mask), jnp.asarray(costs)))
        self.stats["queries"] += b
        self.stats["flops"] += float(np.sum(np.where(mask, costs, 0.0)))
        self.stats["full_flops"] += float(np.sum(costs))
        return ServeResult(
            responses=responses,
            mask=mask,
            cost_fraction=frac,
            member_responses=member_out,
            predicted_quality=r_hat,
        )
