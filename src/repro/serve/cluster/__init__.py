"""Sharded multi-host serving: placement, routing, fan-out, recovery.

The cluster subsystem marries ``repro.sharding`` with the serving stack:

* :class:`PlacementPlan` — which hosts (device groups) each pool member
  runs on, with replica counts, a greedy cost/VRAM-balanced auto-placer
  (:meth:`PlacementPlan.auto`), and dynamic healing
  (:meth:`PlacementPlan.revive_host` / :meth:`PlacementPlan.rebalance`);
* :class:`ClusterRouter` — a placement-aware
  :class:`~repro.serve.backends.MemberBackend` wrapper that routes each
  scheduler batch's per-member sub-batches to their placement (reusing
  the inner backend's BucketLadder jit caches), fails replicated members
  over on host death, escalates unreplicated deaths as
  :class:`~repro.serve.backends.HostFailure`, fans per-host shards out
  to concurrent executors (``fanout=True``), and re-admits recovered
  hosts after a probation window (``host_recovery``/``probation_ticks``);
* :class:`DispatchWorker` — the bounded-inbox thread behind
  ``Scheduler(sync=False)``, so ``submit`` never blocks on a batch;
* :class:`HostExecutor` / :class:`HostExecutorPool` — one bounded-queue
  worker thread per live host, the fabric fan-out shards run on
  (executors retire with dead hosts and respawn lazily after revival).
"""

from repro.serve.cluster.placement import (
    HostSpec,
    MemberPlacement,
    PlacementPlan,
)
from repro.serve.cluster.router import ClusterRouter
from repro.serve.cluster.worker import (
    DispatchWorker,
    HostExecutor,
    HostExecutorPool,
    InboxFull,
    ShardFuture,
)

__all__ = [
    "ClusterRouter",
    "DispatchWorker",
    "HostExecutor",
    "HostExecutorPool",
    "HostSpec",
    "InboxFull",
    "MemberPlacement",
    "PlacementPlan",
    "ShardFuture",
]
