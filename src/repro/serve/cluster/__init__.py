"""Sharded multi-host serving: placement, routing, async dispatch.

The cluster subsystem marries ``repro.sharding`` with the serving stack:

* :class:`PlacementPlan` — which hosts (device groups) each pool member
  runs on, with replica counts and a greedy cost/VRAM-balanced
  auto-placer (:meth:`PlacementPlan.auto`);
* :class:`ClusterRouter` — a placement-aware
  :class:`~repro.serve.backends.MemberBackend` wrapper that routes each
  scheduler batch's per-member sub-batches to their placement (reusing
  the inner backend's BucketLadder jit caches), fails replicated members
  over on host death, and escalates unreplicated deaths as
  :class:`~repro.serve.backends.HostFailure`;
* :class:`DispatchWorker` — the bounded-inbox thread behind
  ``Scheduler(sync=False)``, so ``submit`` never blocks on a batch.
"""

from repro.serve.cluster.placement import (
    HostSpec,
    MemberPlacement,
    PlacementPlan,
)
from repro.serve.cluster.router import ClusterRouter
from repro.serve.cluster.worker import DispatchWorker, InboxFull

__all__ = [
    "ClusterRouter",
    "DispatchWorker",
    "HostSpec",
    "InboxFull",
    "MemberPlacement",
    "PlacementPlan",
]
