"""Sharded multi-host serving: placement, routing, fan-out, recovery.

The cluster subsystem marries ``repro.sharding`` with the serving stack:

* :class:`PlacementPlan` — which hosts (device groups) each pool member
  runs on, with replica counts, a greedy cost/VRAM-balanced auto-placer
  (:meth:`PlacementPlan.auto`), and dynamic healing
  (:meth:`PlacementPlan.revive_host` / :meth:`PlacementPlan.rebalance`);
* :class:`ClusterRouter` — a placement-aware
  :class:`~repro.serve.backends.MemberBackend` wrapper that routes each
  scheduler batch's per-member sub-batches to their placement (reusing
  the inner backend's BucketLadder jit caches), fails replicated members
  over on host death, escalates unreplicated deaths as
  :class:`~repro.serve.backends.HostFailure`, fans per-host shards out
  to concurrent executors (``fanout=True``), and re-admits recovered
  hosts after a probation window (``host_recovery``/``probation_ticks``);
* :class:`HealthMonitor` — deterministic liveness probes feeding
  per-host circuit breakers (closed → open on consecutive probe
  failures → half-open with exponential backoff → closed on a
  successful revival probe), so death and recovery are *observed*
  during maintenance instead of waiting on a dispatch fault or a
  static revival schedule;
* :class:`DispatchWorker` — the bounded-inbox thread behind
  ``Scheduler(sync=False)``, so ``submit`` never blocks on a batch;
* :class:`HostExecutor` / :class:`HostExecutorPool` — one bounded-queue
  worker thread per live host, the fabric fan-out shards run on
  (executors retire with dead hosts and respawn lazily after revival);
  :class:`ShardFuture` supports cancellation, which the router's
  ``shard_deadline_s`` straggler hedging uses to abandon a late shard.
"""

from repro.serve.cluster.health import HealthMonitor
from repro.serve.cluster.placement import (
    HostSpec,
    MemberPlacement,
    PlacementPlan,
)
from repro.serve.cluster.router import ClusterRouter, current_dispatch_host
from repro.serve.cluster.worker import (
    CancelledShard,
    DispatchWorker,
    HostExecutor,
    HostExecutorPool,
    InboxFull,
    ShardFuture,
)

__all__ = [
    "CancelledShard",
    "ClusterRouter",
    "DispatchWorker",
    "HealthMonitor",
    "HostExecutor",
    "HostExecutorPool",
    "HostSpec",
    "InboxFull",
    "MemberPlacement",
    "PlacementPlan",
    "ShardFuture",
    "current_dispatch_host",
]
