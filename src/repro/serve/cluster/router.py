"""Placement-aware routing of member generation across hosts.

:class:`ClusterRouter` is a :class:`~repro.serve.backends.MemberBackend`
wrapper: the engine's per-member generation calls arrive here, the
router resolves the member's *primary* (first alive) replica host from
the :class:`~repro.serve.cluster.placement.PlacementPlan`, installs that
host's mesh rules for the duration of the call, and forwards to the
inner backend — whose per-member jit caches
(:class:`~repro.serve.dispatch.BucketLadder` buckets) are shared across
hosts, so routing never costs a recompile.

Failure semantics (the whole-host extension of PR 3's hedged retry):

* an injected or real host fault surfaces as
  :class:`~repro.serve.backends.HostFailure` carrying the host id;
* the router marks the host dead in the plan.  Members with a replica on
  a surviving host **fail over inside the router** — the batch re-serves
  on the surviving placement and the caller never sees the fault;
* members left with no surviving replica re-raise the ``HostFailure``
  with ``member_idxs`` filled in, and the Scheduler re-serves the batch
  with those members masked out of the knapsack
  (``EnsembleServer.serve_requests(masked_members=...)``).

Host-level failure *injection* lives here too (``host_failures``): the
schedule is keyed on per-host dispatch counts — the n-th generation call
routed to host *h* raises — so a traffic scenario that kills a host is
exactly replayable, like the member-level
:class:`~repro.serve.backends.FailureInjector`.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serve.backends import HostFailure, MaxNewTokens, MemberBackend
from repro.serve.cluster.placement import PlacementPlan
from repro.sharding.api import axis_rules


@dataclasses.dataclass
class ClusterRouter:
    """Routes member generation through a placement plan.

    ``host_failures`` maps a host id to the 0-based *dispatch indices*
    (that host's n-th routed generation call, counted over the router's
    lifetime) that raise :class:`HostFailure` instead of generating."""

    inner: MemberBackend
    plan: PlacementPlan
    host_failures: Dict[int, Sequence[int]] = dataclasses.field(
        default_factory=dict)
    stats: Dict[str, int] = dataclasses.field(default_factory=lambda: {
        "dispatches": 0, "failovers": 0, "host_faults": 0})
    _host_calls: Dict[int, int] = dataclasses.field(default_factory=dict)
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False)

    def __post_init__(self):
        if self.plan.n_members != self.inner.num_members():
            raise ValueError(
                f"plan places {self.plan.n_members} members but the backend "
                f"serves {self.inner.num_members()}")

    # -- MemberBackend protocol -----------------------------------------
    def num_members(self) -> int:
        return self.inner.num_members()

    def generate(self, member_idx: int, records: Sequence,
                 max_new_tokens: MaxNewTokens) -> List[str]:
        while True:
            host = self.plan.primary_host(member_idx)
            if host is None:
                # unroutable: every replica host is dead.  The engine
                # should have masked this member out before generating;
                # reaching here means the death happened mid-batch.
                raise HostFailure(
                    next(iter(self.plan.placements[member_idx].hosts)),
                    member_idxs=(member_idx,))
            try:
                return self._dispatch(host, member_idx, records,
                                      max_new_tokens)
            except HostFailure as hf:
                newly_dead = self.plan.mark_host_dead(hf.host_id)
                with self._lock:
                    self.stats["host_faults"] += 1
                if not newly_dead and self.plan.primary_host(member_idx) is not None:
                    # every member on the dead host has a surviving
                    # replica — fail over and re-serve this sub-batch on
                    # the new primary, invisibly to the caller
                    with self._lock:
                        self.stats["failovers"] += 1
                    continue
                raise HostFailure(hf.host_id, member_idxs=tuple(newly_dead),
                                  cause=hf.cause) from hf.cause

    def _dispatch(self, host: int, member_idx: int, records: Sequence,
                  max_new_tokens: MaxNewTokens) -> List[str]:
        with self._lock:
            k = self._host_calls.get(host, 0)
            self._host_calls[host] = k + 1
            self.stats["dispatches"] += 1
        if k in tuple(self.host_failures.get(host, ())):
            raise HostFailure(host, cause=RuntimeError(
                f"injected host failure: host {host}, dispatch {k}"))
        rules = self.plan.member_rules(member_idx)
        ctx = axis_rules(rules) if rules is not None else contextlib.nullcontext()
        with ctx:
            return self.inner.generate(member_idx, records, max_new_tokens)

    def dead_members(self) -> List[int]:
        """Members with no surviving replica — the Scheduler pre-masks
        these out of the knapsack for every batch formed after a host
        death, so only the batch in flight at the fault pays a retry."""
        return self.plan.dead_members()

    # -- optional protocol hooks forward to the wrapped backend ----------
    def warm(self, shapes: Sequence) -> None:
        warm = getattr(self.inner, "warm", None)
        if callable(warm):
            warm(shapes)

    def compiles(self) -> int:
        compiles = getattr(self.inner, "compiles", None)
        return compiles() if callable(compiles) else 0

    # -- introspection ---------------------------------------------------
    def split_by_host(self, member_idxs: Sequence[int]
                      ) -> Dict[Optional[int], Tuple[int, ...]]:
        """Group members by the host their generation would route to —
        the per-placement sub-batches of one scheduler batch.  ``None``
        keys members that cannot route (all replicas dead)."""
        out: Dict[Optional[int], List[int]] = {}
        for j in member_idxs:
            out.setdefault(self.plan.primary_host(j), []).append(j)
        return {h: tuple(js) for h, js in out.items()}
