"""Placement-aware routing of member generation across hosts.

:class:`ClusterRouter` is a :class:`~repro.serve.backends.MemberBackend`
wrapper: the engine's per-member generation calls arrive here, the
router resolves the member's *primary* (first alive) replica host from
the :class:`~repro.serve.cluster.placement.PlacementPlan`, installs that
host's mesh rules for the duration of the call, and forwards to the
inner backend — whose per-member jit caches
(:class:`~repro.serve.dispatch.BucketLadder` buckets) are shared across
hosts, so routing never costs a recompile.

Fan-out (``fanout=True``) turns the router from a routing table into a
concurrent executor fabric: one batch's generation calls are *planned*
sequentially on the serving thread (routing, per-host dispatch counts,
and injected-failure consumption advance in exactly the order the
sequential path would produce them), then the per-host shards execute
concurrently on a :class:`~repro.serve.cluster.worker.HostExecutorPool`
— one bounded-queue worker thread per live host.  Because the plan pass
is sequential and each host's executor runs its shard FIFO, fan-out may
change wall-clock but never outputs: traces and responses are
byte-identical to sequential routing (pinned per preset scenario by the
chaos suite).  The one documented asymmetry: a *real* (non-injected)
mid-shard fault aborts only its own shard, so sibling shards may consume
inner-backend call counters the aborting sequential path would not have
reached — injected schedules, which are resolved at planning time, never
hit this.

Failure semantics (the whole-host extension of PR 3's hedged retry):

* an injected or real host fault surfaces as
  :class:`~repro.serve.backends.HostFailure` carrying the host id;
* the router marks the host dead in the plan (and retires its executor).
  Members with a replica on a surviving host **fail over inside the
  router** — the batch re-serves on the surviving placement and the
  caller never sees the fault;
* members left with no surviving replica re-raise the ``HostFailure``
  with ``member_idxs`` filled in, and the Scheduler re-serves the batch
  with those members masked out of the knapsack
  (``EnsembleServer.serve_requests(masked_members=...)``).

Recovery makes death non-final: ``host_recovery`` schedules the logical
tick at which a dead host is healthy again, and tick-driven maintenance
(:meth:`maintain`, called by the Scheduler with in-flight shards
drained) re-admits it once a ``probation_ticks`` window has elapsed —
routing returns to the revived primary, and the Scheduler stops
pre-masking its members.  ``rebalance=True`` additionally re-places
members that lost replica redundancy onto the least-loaded surviving
hosts at the next maintenance pass.

Installing a :class:`~repro.serve.cluster.health.HealthMonitor`
(``health=``) upgrades recovery from scheduled to *observed*: the
maintenance pass runs the monitor's deterministic liveness probes,
whose circuit breakers mark hosts dead on consecutive probe failures
(no dispatch has to explode first) and revive them through half-open
probes with exponential backoff — strictly faster than schedule-driven
revival, which must additionally sit out its probation window.

Grey failures — hosts alive but slow — get two defenses.
``host_stragglers`` + ``hedge_stragglers=True`` is the *deterministic*
one: dispatch indices scheduled as stragglers are re-routed at
consume time to an alive replica (the replica's dispatch counter
advances too), identically in sequential and fan-out routing, so
hedged traces stay byte-identical.  ``shard_deadline_s`` is the
*wall-clock* one (fan-out only): a shard that misses its deadline is
cancelled and its unfinished calls re-served on replica hosts
(earliest completion wins — a late original result is byte-identical
anyway).  Like real mid-shard faults, wall-clock hedges bypass
dispatch counters; injected schedules never hit this path.

Host-level failure *injection* lives here too (``host_failures``): the
schedule is keyed on per-host dispatch counts — the n-th generation call
routed to host *h* raises — so a traffic scenario that kills a host is
exactly replayable, like the member-level
:class:`~repro.serve.backends.FailureInjector`.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serve.backends import (
    GenerationCall,
    HostFailure,
    MaxNewTokens,
    MemberBackend,
    MemberFailure,
)
from repro.serve.cluster.health import HealthMonitor
from repro.serve.cluster.placement import PlacementPlan
from repro.serve.cluster.worker import HostExecutorPool
from repro.sharding.api import axis_rules

# The host a generation call is executing on, visible to the wrapped
# backend (set around every inner.generate).  Host-aware test/bench
# wrappers (e.g. a straggler floor that slows one host's wall clock
# without touching the logical trace) key on this.
_CURRENT_HOST: contextvars.ContextVar[Optional[int]] = contextvars.ContextVar(
    "repro_serve_current_host", default=None)


def current_dispatch_host() -> Optional[int]:
    """The placement host of the generation call running on this thread,
    or None outside a routed call."""
    return _CURRENT_HOST.get()


@dataclasses.dataclass
class _PlannedCall:
    """One generation call after the routing plan pass: the host is
    pinned (execution must not re-resolve it) and the dispatch index is
    already consumed from the host's injection schedule."""

    order: int  # position in the batch's call list (== member order)
    call: GenerationCall
    host: int
    dispatch_idx: int


@dataclasses.dataclass
class ClusterRouter:
    """Routes member generation through a placement plan.

    ``host_failures`` maps a host id to the 0-based *dispatch indices*
    (that host's n-th routed generation call, counted over the router's
    lifetime) that raise :class:`HostFailure` instead of generating.
    ``host_recovery`` maps a host id to the logical ticks at which it
    recovers (consumed in order — a host can die, revive, and die
    again); ``probation_ticks`` delays each re-admission past the
    recovery tick.  ``fanout=True`` executes per-host shards
    concurrently on a :class:`HostExecutorPool`.

    ``health`` installs a :class:`HealthMonitor` whose probes run inside
    the maintenance pass (probe-opened deaths and half-open revivals —
    use it *instead of* ``host_recovery``, whose schedule it replaces).
    ``host_stragglers`` maps a host id to the dispatch indices that are
    grey-slow on it; with ``hedge_stragglers=True`` those dispatches
    re-route to an alive replica at consume time.  ``shard_deadline_s``
    bounds each fan-out shard's wall-clock service; a late shard is
    cancelled and hedged onto replica hosts."""

    inner: MemberBackend
    plan: PlacementPlan
    host_failures: Dict[int, Sequence[int]] = dataclasses.field(
        default_factory=dict)
    fanout: bool = False
    executor_capacity: int = 8
    host_recovery: Dict[int, Sequence[int]] = dataclasses.field(
        default_factory=dict)
    probation_ticks: int = 0
    rebalance: bool = False
    health: Optional[HealthMonitor] = None
    host_stragglers: Dict[int, Sequence[int]] = dataclasses.field(
        default_factory=dict)
    hedge_stragglers: bool = False
    shard_deadline_s: Optional[float] = None
    record_audit: bool = False
    stats: Dict[str, int] = dataclasses.field(default_factory=lambda: {
        "dispatches": 0, "failovers": 0, "host_faults": 0,
        "fanout_batches": 0, "shards": 0, "revivals": 0, "rebalanced": 0,
        "straggler_hedges": 0, "stragglers_unhedged": 0, "shard_hedges": 0,
        "probes": 0, "probe_deaths": 0, "probe_revivals": 0})
    # (host, member, dispatch_idx, host_was_dead) per routed dispatch —
    # the chaos property suite's no-dead-dispatch evidence
    audit: List[Tuple[int, int, int, bool]] = dataclasses.field(
        default_factory=list)
    _host_calls: Dict[int, int] = dataclasses.field(default_factory=dict)
    _recovered: Dict[int, int] = dataclasses.field(default_factory=dict)
    _faults_maintained: int = 0  # host_faults already seen by maintain()
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False)
    _pool: Optional[HostExecutorPool] = dataclasses.field(
        default=None, repr=False)

    def __post_init__(self):
        if self.plan.n_members != self.inner.num_members():
            raise ValueError(
                f"plan places {self.plan.n_members} members but the backend "
                f"serves {self.inner.num_members()}")
        if self.health is not None and self.health.plan is not self.plan:
            raise ValueError(
                "health monitor must observe the router's own plan")
        if self.fanout:
            self._pool = HostExecutorPool(capacity=self.executor_capacity)

    # -- MemberBackend protocol -----------------------------------------
    def num_members(self) -> int:
        return self.inner.num_members()

    def generate(self, member_idx: int, records: Sequence,
                 max_new_tokens: MaxNewTokens) -> List[str]:
        while True:
            try:
                routed = self._consume_routed(member_idx)
                if routed is None:
                    # unroutable: every replica host is dead.  The engine
                    # should have masked this member out before generating;
                    # reaching here means the death happened mid-batch.
                    raise HostFailure(
                        next(iter(self.plan.placements[member_idx].hosts)),
                        member_idxs=(member_idx,))
                return self._run(routed[0], member_idx, records,
                                 max_new_tokens)
            except HostFailure as hf:
                if hf.member_idxs:
                    raise  # already escalated (unroutable / stranded)
                newly_dead = self._absorb_host_fault(hf.host_id)
                if not newly_dead and self.plan.primary_host(member_idx) is not None:
                    # every member on the dead host has a surviving
                    # replica — fail over and re-serve this sub-batch on
                    # the new primary, invisibly to the caller
                    with self._lock:
                        self.stats["failovers"] += 1
                    continue
                raise HostFailure(hf.host_id, member_idxs=tuple(newly_dead),
                                  cause=hf.cause) from hf.cause

    def _consume_routed(self, member_idx: int) -> Optional[Tuple[int, int]]:
        """Resolve the member's primary host and consume its dispatch
        index (raising any injected fault).  When straggler hedging is
        armed and this dispatch index is grey-slow on its host, re-route
        to the first alive replica and consume *its* dispatch index too
        — the hedge is part of the deterministic consume order, so
        sequential and fan-out routing hedge (and trace) identically.
        Returns ``(host, dispatch_idx)``, or None when unroutable."""
        host = self.plan.primary_host(member_idx)
        if host is None:
            return None
        k = self._consume_dispatch(host, member_idx)
        if k in tuple(self.host_stragglers.get(host, ())):
            if not self.hedge_stragglers:
                with self._lock:
                    self.stats["stragglers_unhedged"] += 1
            else:
                alt = self.plan.replica_host(member_idx, avoid=(host,))
                if alt is None:
                    with self._lock:
                        self.stats["stragglers_unhedged"] += 1
                else:
                    with self._lock:
                        self.stats["straggler_hedges"] += 1
                    k = self._consume_dispatch(alt, member_idx)
                    host = alt
        return host, k

    def _consume_dispatch(self, host: int, member_idx: int) -> int:
        """Advance the host's dispatch counter (raising its injected
        failure if this index is scheduled) — the single point every
        routed generation call, sequential or fanned out, passes through
        in deterministic order."""
        with self._lock:
            k = self._host_calls.get(host, 0)
            self._host_calls[host] = k + 1
            self.stats["dispatches"] += 1
            if self.record_audit:
                self.audit.append(
                    (host, member_idx, k, host in self.plan.dead_hosts))
        if k in tuple(self.host_failures.get(host, ())):
            raise HostFailure(host, cause=RuntimeError(
                f"injected host failure: host {host}, dispatch {k}"))
        return k

    def _run(self, host: int, member_idx: int, records: Sequence,
             max_new_tokens: MaxNewTokens) -> List[str]:
        """The actual inner generate, under the pinned host's mesh rules."""
        rules = self.plan.member_rules(member_idx, host=host)
        ctx = axis_rules(rules) if rules is not None else contextlib.nullcontext()
        token = _CURRENT_HOST.set(host)
        try:
            with ctx:
                return self.inner.generate(member_idx, records, max_new_tokens)
        finally:
            _CURRENT_HOST.reset(token)

    def _absorb_host_fault(self, host_id: int) -> List[int]:
        """Mark a faulted host dead and retire its executor; returns the
        members the death newly leaves with no surviving replica (empty
        means every affected member can fail over)."""
        newly_dead = self.plan.mark_host_dead(host_id)
        with self._lock:
            self.stats["host_faults"] += 1
        if self._pool is not None:
            self._pool.retire(host_id)
        return newly_dead

    # -- fan-out ---------------------------------------------------------
    def generate_many(self, calls: Sequence[GenerationCall]
                      ) -> List[List[str]]:
        """Serve one batch's member generation calls, fanning per-host
        shards out to the executor pool when ``fanout=True``.

        The contract mirrors the engine's sequential loop exactly:
        results come back in call order; a failed member raises
        :class:`MemberFailure`; a host death that strands members raises
        :class:`HostFailure` with ``member_idxs`` — after every call the
        sequential path would have completed has completed."""
        if not self.fanout or self._pool is None or len(calls) <= 1:
            return [self._sequential_call(c) for c in calls]
        planned, escalation = self._plan_batch(calls)
        results = self._execute_shards(planned)
        if escalation is not None:
            raise escalation
        return [results[i] for i in range(len(calls))]

    def _sequential_call(self, call: GenerationCall) -> List[str]:
        try:
            return self.generate(call.member_idx, call.records,
                                 call.max_new_tokens)
        except (MemberFailure, HostFailure):
            raise
        except Exception as exc:
            raise MemberFailure(call.member_idx, exc) from exc

    def _plan_batch(self, calls: Sequence[GenerationCall]
                    ) -> Tuple[List[_PlannedCall], Optional[HostFailure]]:
        """Sequential routing pass: resolve every call's host and consume
        dispatch indices (and injected failures) in exactly the order the
        sequential path would.  Returns the executable prefix plus the
        escalation that truncated it, if any — calls past an escalation
        are never dispatched, matching sequential abort semantics."""
        planned: List[_PlannedCall] = []
        for order, call in enumerate(calls):
            j = call.member_idx
            while True:
                try:
                    routed = self._consume_routed(j)
                except HostFailure as hf:
                    newly_dead = self._absorb_host_fault(hf.host_id)
                    if not newly_dead and self.plan.primary_host(j) is not None:
                        with self._lock:
                            self.stats["failovers"] += 1
                        continue  # fail over: re-plan this call
                    return planned, HostFailure(
                        hf.host_id, member_idxs=tuple(newly_dead),
                        cause=hf.cause)
                if routed is None:
                    first = next(iter(self.plan.placements[j].hosts))
                    return planned, HostFailure(first, member_idxs=(j,))
                planned.append(_PlannedCall(order, call, routed[0], routed[1]))
                break
        return planned, None

    def _execute_shards(self, planned: List[_PlannedCall]
                        ) -> Dict[int, List[str]]:
        """Run the planned calls, one concurrent shard per host.  A shard
        aborts at its first failing call; after joining every shard the
        earliest failure (in call order — the one sequential routing
        would have hit first) is re-raised with member attribution.
        Absorbable host faults (every affected member keeps a surviving
        replica) are healed in place: the faulted call AND the aborted
        shard tail re-serve on their new primaries before returning."""
        shards: Dict[int, List[_PlannedCall]] = {}
        for p in planned:
            shards.setdefault(p.host, []).append(p)
        with self._lock:
            self.stats["fanout_batches"] += 1
            self.stats["shards"] += len(shards)

        def shard_fn(shard: List[_PlannedCall], done: Dict[int, List[str]]):
            # `done` is shared with the joining thread so a deadline
            # hedge can see (and keep) whatever the straggling shard
            # already produced; dict item writes are atomic under the GIL
            for p in shard:
                try:
                    done[p.order] = self._run(p.host, p.call.member_idx,
                                              p.call.records,
                                              p.call.max_new_tokens)
                except BaseException as exc:
                    return (p.order, p.call.member_idx, exc)
            return None

        results: Dict[int, List[str]] = {}
        errors: List[Tuple[int, int, BaseException]] = []
        pending = []
        for host, shard in sorted(shards.items()):
            done: Dict[int, List[str]] = {}
            if host in self.plan.dead_hosts:
                # the host died later in the planning pass, after these
                # earlier dispatches were already consumed (sequential
                # routing would have run them pre-death too).  Run the
                # shard on the serving thread: submitting would silently
                # respawn an executor the death already retired.
                err = shard_fn(shard, done)
                results.update(done)
                if err is not None:
                    errors.append(err)
            else:
                pending.append((shard, done, self._pool.submit(
                    host, lambda s=shard, d=done: shard_fn(s, d))))
        for shard, done, f in pending:
            try:
                err = f.result(timeout=self.shard_deadline_s)
            except TimeoutError:
                # straggling shard: cancel (drops it if still queued;
                # best-effort if running) and re-serve its unfinished
                # calls on replica hosts.  Earliest completion wins —
                # a late original result is byte-identical, so keeping
                # whichever landed first never changes outputs.
                f.cancel()
                with self._lock:
                    self.stats["shard_hedges"] += 1
                err = self._hedge_shard(shard, done)
            results.update(done)
            if err is not None:
                errors.append(err)
        for order, j, exc in sorted(errors, key=lambda e: e[0]):
            if isinstance(exc, HostFailure):
                newly_dead = self._absorb_host_fault(exc.host_id)
                if not newly_dead and self.plan.primary_host(j) is not None:
                    with self._lock:
                        self.stats["failovers"] += 1
                    continue  # healed below with the aborted shard tail
                raise HostFailure(exc.host_id, member_idxs=tuple(newly_dead),
                                  cause=exc.cause) from exc.cause
            if isinstance(exc, MemberFailure):
                raise exc
            raise MemberFailure(j, exc) from exc
        # every fault was absorbable: re-serve the faulted calls and the
        # aborted shard tails on their new primaries.  _sequential_call
        # keeps the contract — a generic error here surfaces as
        # MemberFailure(j), so the Scheduler hedges one member instead of
        # failing every sibling future.
        for p in planned:
            if p.order not in results:
                results[p.order] = self._sequential_call(p.call)
        return results

    def _hedge_shard(self, shard: List[_PlannedCall],
                     done: Dict[int, List[str]]
                     ) -> Optional[Tuple[int, int, BaseException]]:
        """Re-serve a timed-out shard's unfinished calls on replica
        hosts (falling back to the original when no replica is alive),
        inline on the serving thread.  Wall-clock hedges carry the same
        documented real-fault asymmetry as mid-shard aborts: they bypass
        dispatch counters, so injected schedules are never double-fired.
        The straggler keeps running; ``setdefault`` lets the earliest
        completion win."""
        for p in shard:
            if p.order in done:
                continue
            alt = self.plan.replica_host(p.call.member_idx, avoid=(p.host,))
            target = p.host if alt is None else alt
            try:
                res = self._run(target, p.call.member_idx, p.call.records,
                                p.call.max_new_tokens)
            except BaseException as exc:
                return (p.order, p.call.member_idx, exc)
            done.setdefault(p.order, res)
        return None

    # -- recovery maintenance --------------------------------------------
    def _next_revive_tick(self, host_id: int) -> Optional[int]:
        """The tick at which the host's next scheduled recovery (plus
        probation) completes, or None when none remains."""
        ticks = tuple(self.host_recovery.get(host_id, ()))
        consumed = self._recovered.get(host_id, 0)
        if consumed >= len(ticks):
            return None
        return ticks[consumed] + self.probation_ticks

    def maintenance_pending(self, now: int) -> bool:
        """Whether :meth:`maintain` might change placement state at this
        tick.  Deliberately computed from *static* schedule state only
        (unconsumed recovery entries whose tick has arrived; rebalance
        armed) — never from live host health, which an in-flight async
        batch may still be about to change.  The Scheduler drains
        (``join``) exactly when this answers True, then lets
        :meth:`maintain` decide precisely on the drained state, so sync
        and async modes make identical maintenance decisions at
        identical ticks."""
        if self.health is not None and self.health.probe_due(now):
            return True  # probe_due is pure in (tick, interval): static
        for h in self.host_recovery:
            t = self._next_revive_tick(h)
            if t is not None and now >= t:
                return True
        if not self.rebalance:
            return False
        # rebalance can only newly apply after a host fault: pending while
        # the static failure schedule still has unfired entries (true in
        # both dispatch modes regardless of worker progress — a stale
        # counter read only errs toward True), or while a fault maintain()
        # has not yet seen awaits handling.  A healthy fleet with its
        # schedule exhausted never pays the drain barrier.
        with self._lock:
            faults, calls = self.stats["host_faults"], dict(self._host_calls)
        if faults > self._faults_maintained:
            return True
        return any(any(k >= calls.get(h, 0) for k in tuple(ks))
                   for h, ks in self.host_failures.items())

    def maintain(self, now: int) -> List[dict]:
        """Apply due revivals and rebalances; returns trace-ready event
        dicts.  MUST be called with no shards in flight (the Scheduler
        joins first) — migration never races generation.  A recovery
        entry whose tick arrives while its host is alive (never died, or
        already revived) is consumed silently: recovery ticks are
        absolute scenario time, not death-relative."""
        events: List[dict] = []
        if self.health is not None and self.health.probe_due(now):
            probe_events = self.health.run_probes(now)
            for ev in probe_events:
                kind = ev["event"]
                with self._lock:
                    if kind == "probe":
                        self.stats["probes"] += 1
                    elif kind == "probe_death":
                        self.stats["probe_deaths"] += 1
                    elif kind == "probe_revive":
                        self.stats["probe_revivals"] += 1
                        self.stats["revivals"] += 1
                if kind == "probe_death" and self._pool is not None:
                    self._pool.retire(ev["host"])
            events.extend(probe_events)
        for h in sorted(self.host_recovery):
            t = self._next_revive_tick(h)
            if t is None or now < t:
                continue
            self._recovered[h] = self._recovered.get(h, 0) + 1
            if h not in self.plan.dead_hosts:
                continue  # moot: nothing to revive at its scheduled tick
            restored = self.plan.revive_host(h)
            with self._lock:
                self.stats["revivals"] += 1
            events.append({"event": "revive", "host": h,
                           "recovered": restored,
                           "probation": self.probation_ticks})
        if self.rebalance:
            for j, h in self.plan.rebalance():
                with self._lock:
                    self.stats["rebalanced"] += 1
                events.append({"event": "rebalance", "member": j, "host": h})
            with self._lock:
                self._faults_maintained = self.stats["host_faults"]
        return events

    def dead_members(self) -> List[int]:
        """Members with no surviving replica — the Scheduler snapshots
        this once per batch at dispatch time (an atomic read under the
        plan's lock) and pre-masks them out of the knapsack, so only the
        batch in flight at the fault pays a retry."""
        return self.plan.dead_members()

    def close(self) -> None:
        """Stop the fan-out executor threads (no-op in sequential mode)."""
        if self._pool is not None:
            self._pool.close()

    # -- optional protocol hooks forward to the wrapped backend ----------
    def warm(self, shapes: Sequence) -> None:
        warm = getattr(self.inner, "warm", None)
        if callable(warm):
            warm(shapes)

    def compiles(self) -> int:
        compiles = getattr(self.inner, "compiles", None)
        return compiles() if callable(compiles) else 0

    # -- introspection ---------------------------------------------------
    def split_by_host(self, member_idxs: Sequence[int]
                      ) -> Dict[Optional[int], Tuple[int, ...]]:
        """Group members by the host their generation would route to —
        the per-placement sub-batches of one scheduler batch.  ``None``
        keys members that cannot route (all replicas dead)."""
        out: Dict[Optional[int], List[int]] = {}
        for j in member_idxs:
            out.setdefault(self.plan.primary_host(j), []).append(j)
        return {h: tuple(js) for h, js in out.items()}
