"""Probe-driven host health: liveness observed, not scheduled.

:class:`HealthMonitor` replaces schedule-only revival
(``ClusterRouter.host_recovery`` consumed blindly at its tick) with
*observed* liveness: at every ``probe_interval`` scheduler ticks the
monitor issues one deterministic probe per host and feeds the outcomes
through a per-host circuit breaker:

* **closed** — the host is routable.  Probes run every interval; a
  probe failure increments a consecutive-failure counter, and at
  ``probe_failures`` consecutive failures the breaker **opens**: the
  host is marked dead in the :class:`PlacementPlan` (its members fail
  over or are pre-masked) *without waiting for a dispatch to explode* —
  the crash-on-probe path.
* **open** — the host is dead (probe-opened, or dispatch-opened by a
  :class:`~repro.serve.backends.HostFailure` the router absorbed; the
  monitor adopts those deaths at its next pass).  A newly opened
  breaker is immediately eligible for a **half-open** probe; each
  *failed* half-open probe backs the next attempt off exponentially
  (``backoff_ticks`` doubling per failure, capped at ``backoff_cap``) —
  a host that stays down is probed ever more rarely, never hammered.
* **half-open → closed** — a successful half-open probe revives the
  host through :meth:`PlacementPlan.revive_host` (the router follows up
  with :meth:`PlacementPlan.rebalance` when armed) and resets the
  failure count and backoff.

Probe outcomes are DETERMINISTIC, in the same style as the
member-level :class:`~repro.serve.backends.FailureInjector` and the
router's ``host_failures`` — keyed on per-host *probe indices* and
logical ticks, never wall time:

* ``probe_faults`` maps a host to the 0-based probe indices (that
  host's n-th probe over the monitor's lifetime) that FAIL regardless
  of underlying health — one isolated index is a flaky probe (stays
  under the threshold, trace-visible, harmless); ``probe_failures``
  consecutive indices are a crash-on-probe kill.
* ``recovery`` maps a host to the logical ticks at which its
  *underlying* health returns (consumed in order, like the router's
  schedule-driven ``host_recovery`` — which this replaces when a
  monitor is installed).  A half-open probe succeeds exactly when an
  unconsumed recovery tick has arrived and the probe index is not
  scheduled to fault.

Because probes run only inside the router's drained maintenance pass
(:meth:`ClusterRouter.maintain`, behind the static
``maintenance_pending`` decision) and consult only schedules and
drained plan state, the trace they produce is byte-identical across
sync/async dispatch and sequential/fan-out routing — the chaos tier's
anchor invariant survives the health subsystem.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from repro.serve.cluster.placement import PlacementPlan

CLOSED = "closed"
OPEN = "open"


@dataclasses.dataclass
class _Breaker:
    """Per-host circuit-breaker state (all mutations happen inside the
    drained maintenance pass — no lock needed)."""

    state: str = CLOSED
    failures: int = 0  # consecutive probe failures while closed
    probes: int = 0  # per-host probe index (the fault-schedule key)
    backoff: int = 1  # ticks until the next half-open attempt
    next_probe: int = 0  # earliest tick an open breaker may half-open probe


@dataclasses.dataclass
class HealthMonitor:
    """Deterministic liveness probes + per-host circuit breakers.

    ``probe_failures`` is the consecutive-failure threshold that opens a
    closed breaker; ``backoff_ticks`` seeds the exponential half-open
    backoff (doubled per failed half-open probe, capped at
    ``backoff_cap``).  :meth:`run_probes` mutates the plan (deaths and
    revivals) and returns trace-ready event dicts; the router owns
    executor retirement and stats."""

    plan: PlacementPlan
    probe_interval: int = 1
    probe_failures: int = 2
    probe_faults: Dict[int, Sequence[int]] = dataclasses.field(
        default_factory=dict)
    recovery: Dict[int, Sequence[int]] = dataclasses.field(
        default_factory=dict)
    backoff_ticks: int = 1
    backoff_cap: int = 8
    _breakers: Dict[int, _Breaker] = dataclasses.field(default_factory=dict)
    _recovered: Dict[int, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.probe_interval < 1:
            raise ValueError("probe_interval must be >= 1")
        if self.probe_failures < 1:
            raise ValueError("probe_failures must be >= 1")
        if self.backoff_ticks < 1:
            raise ValueError("backoff_ticks must be >= 1")

    # ------------------------------------------------------------------
    def probe_due(self, now: int) -> bool:
        """Whether a probe pass runs at this tick.  A pure function of
        the tick and the static interval — the property the Scheduler's
        ``maintenance_pending`` drain barrier needs to decide
        identically in sync and async dispatch modes."""
        return now > 0 and now % self.probe_interval == 0

    def breaker(self, host_id: int) -> _Breaker:
        b = self._breakers.get(host_id)
        if b is None:
            b = self._breakers[host_id] = _Breaker(
                backoff=self.backoff_ticks)
        return b

    def state(self, host_id: int) -> str:
        """The breaker state routing sees (dispatch-observed deaths the
        monitor has not yet adopted still report closed here)."""
        return self.breaker(host_id).state

    # ------------------------------------------------------------------
    def _probe_ok(self, host_id: int, probe_idx: int, now: int,
                  half_open: bool) -> bool:
        if probe_idx in tuple(self.probe_faults.get(host_id, ())):
            return False
        if not half_open:
            return True  # a routable host answers unless a fault is scheduled
        # half-open: the dead host answers once its underlying health has
        # returned (the next unconsumed recovery tick has arrived)
        ticks = tuple(self.recovery.get(host_id, ()))
        consumed = self._recovered.get(host_id, 0)
        return consumed < len(ticks) and ticks[consumed] <= now

    def run_probes(self, now: int) -> List[dict]:
        """One probe pass over every host, in host order.  MUST run on
        drained state (the router's maintenance pass) — probe-driven
        deaths and revivals mutate the plan.  Returns trace-ready event
        dicts: ``probe`` per issued probe, ``probe_death`` when a
        breaker opens, ``probe_revive`` when a half-open probe closes
        one."""
        events: List[dict] = []
        for spec in self.plan.hosts:
            h = spec.host_id
            b = self.breaker(h)
            if b.state == CLOSED and h in self.plan.dead_hosts:
                # adopt a dispatch-observed death: the breaker opens with
                # no event of its own (the fault already traced as a
                # host_hedge) and is immediately probe-eligible
                b.state = OPEN
                b.failures = 0
                b.backoff = self.backoff_ticks
                b.next_probe = now
            if b.state == CLOSED:
                k = b.probes
                b.probes += 1
                ok = self._probe_ok(h, k, now, half_open=False)
                events.append({"event": "probe", "host": h, "probe": k,
                               "ok": ok, "half_open": False})
                if ok:
                    b.failures = 0
                    continue
                b.failures += 1
                if b.failures < self.probe_failures:
                    continue
                stranded = self.plan.mark_host_dead(h)
                b.state = OPEN
                b.backoff = self.backoff_ticks
                b.next_probe = now + b.backoff
                events.append({"event": "probe_death", "host": h,
                               "failures": b.failures,
                               "stranded": stranded})
            else:  # OPEN: half-open probe, gated by the backoff window
                if now < b.next_probe:
                    continue
                k = b.probes
                b.probes += 1
                ok = self._probe_ok(h, k, now, half_open=True)
                events.append({"event": "probe", "host": h, "probe": k,
                               "ok": ok, "half_open": True})
                if ok:
                    self._recovered[h] = self._recovered.get(h, 0) + 1
                    restored = self.plan.revive_host(h)
                    b.state = CLOSED
                    b.failures = 0
                    b.backoff = self.backoff_ticks
                    events.append({"event": "probe_revive", "host": h,
                                   "recovered": restored,
                                   "after_probes": k + 1})
                else:
                    b.next_probe = now + b.backoff
                    b.backoff = min(b.backoff * 2, self.backoff_cap)
        return events
