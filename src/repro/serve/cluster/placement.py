"""Member-to-host placement over a sharded device fleet.

A :class:`PlacementPlan` assigns every pool member to one or more
logical *hosts* — contiguous device groups carved out of the fleet by
:func:`repro.sharding.api.partition_devices` — and knows how to stand up
the per-host mesh (:func:`repro.sharding.api.host_mesh`) and the
per-member :class:`~repro.sharding.api.AxisRules` that member's
generation should run under.  Plans are *logical first*: a plan built
without real devices (single-device CI, the behavioural simulator) has
the same routing semantics as one spanning an 8-host forced-device
mesh, so every cluster test runs anywhere.

Two constructors cover the common cases:

* :meth:`PlacementPlan.auto` — the greedy cost/VRAM-balanced placer:
  members are placed heaviest-first onto the host with the least
  accumulated weight (bf16 parameter bytes, which under Kaplan costs is
  also proportional to per-token FLOPs — balancing one balances both),
  with replicas forced onto distinct hosts so a single host failure
  never kills a replicated member.
* :meth:`PlacementPlan.round_robin` — member *i* on host ``i % n`` (the
  permutation-property tests sweep arbitrary assignments on top).

Host death is a plan-level state change: :meth:`mark_host_dead` flips
the host and returns the members left with no surviving replica — the
set the Scheduler masks out of the knapsack re-solve (see
:class:`~repro.serve.backends.HostFailure`).  Plans are also *dynamic*:
:meth:`revive_host` re-admits one recovered host (the router gates it
behind a probation window), and :meth:`rebalance` re-places members that
lost replica redundancy onto the least-loaded surviving hosts, so a
long-running scheduler heals instead of shrinking monotonically.  All
state-changing and state-snapshotting methods serialize on one RLock:
with fan-out executors generating on host threads and tick-driven
maintenance mutating the plan from the scheduler thread, every reader
gets a consistent point-in-time view (see
``Scheduler._serve_batch``'s per-batch dead-member snapshot).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

from repro.sharding.api import (
    AxisRules,
    MeshAxes,
    default_axis_rules,
    host_mesh,
    partition_devices,
)


@dataclasses.dataclass(frozen=True)
class HostSpec:
    """One logical host: an id plus the devices it owns (possibly none —
    a logical-only plan routes identically without touching jax)."""

    host_id: int
    devices: Tuple = ()

    @property
    def n_devices(self) -> int:
        return len(self.devices)


@dataclasses.dataclass(frozen=True)
class MemberPlacement:
    """Where one pool member runs.

    ``hosts`` lists replica hosts in preference order (primary first);
    ``mesh_axes`` optionally overrides the logical→mesh axis rules for
    this member's generation (e.g. a big member sharding ``mlp`` over the
    whole host while a small one replicates); ``weight`` is the placer's
    balance metric (bf16 parameter bytes)."""

    member_idx: int
    hosts: Tuple[int, ...]
    weight: float = 0.0
    mesh_axes: Optional[Mapping[str, MeshAxes]] = None


def _member_weight(spec) -> float:
    """bf16 parameter bytes — the VRAM footprint, and (×2 FLOPs/param/token
    under Kaplan) the cost proxy the greedy placer balances."""
    params_b = getattr(spec, "params_b", None)
    if params_b is None:
        return 1.0
    return float(params_b) * 1e9 * 2.0


class PlacementPlan:
    """Assignment of pool members onto logical hosts (with optional meshes)."""

    def __init__(self, hosts: Sequence[HostSpec],
                 placements: Sequence[MemberPlacement]):
        if not hosts:
            raise ValueError("a placement plan needs at least one host")
        self.hosts = list(hosts)
        self.placements = list(placements)
        host_ids = {h.host_id for h in self.hosts}
        if len(host_ids) != len(self.hosts):
            raise ValueError("duplicate host ids in plan")
        for p in self.placements:
            if not p.hosts:
                raise ValueError(f"member {p.member_idx} placed on no host")
            missing = [h for h in p.hosts if h not in host_ids]
            if missing:
                raise ValueError(
                    f"member {p.member_idx} placed on unknown hosts {missing}")
            if len(set(p.hosts)) != len(p.hosts):
                raise ValueError(
                    f"member {p.member_idx} has duplicate replica hosts")
        self.dead_hosts: Set[int] = set()
        # replica target rebalance() restores members toward (the widest
        # replica set any member was built with)
        self.target_replicas = max(len(p.hosts) for p in self.placements)
        self._mesh_cache: Dict[int, object] = {}
        self._lock = threading.RLock()

    # -- constructors ---------------------------------------------------
    @classmethod
    def auto(cls, pool: Sequence, n_hosts: int, replicas: int = 1,
             devices: Optional[Sequence] = None,
             mesh_axes: Optional[Mapping[int, Mapping[str, MeshAxes]]] = None,
             ) -> "PlacementPlan":
        """Greedy balanced placement of ``pool`` over ``n_hosts`` hosts.

        Members are placed heaviest-first; each replica goes to the
        least-loaded host not already holding one (load = Σ placed member
        weight).  Ties break toward the lower host id, so the plan is a
        pure function of the pool — two processes building it agree
        without coordination."""
        if n_hosts < 1:
            raise ValueError("n_hosts must be >= 1")
        if not 1 <= replicas <= n_hosts:
            raise ValueError(f"replicas={replicas} must be in [1, {n_hosts}]")
        groups = (partition_devices(devices, n_hosts) if devices
                  else ((),) * n_hosts)
        hosts = [HostSpec(h, groups[h]) for h in range(n_hosts)]
        load = [0.0] * n_hosts
        order = sorted(range(len(pool)),
                       key=lambda j: (-_member_weight(pool[j]), j))
        chosen: Dict[int, Tuple[int, ...]] = {}
        for j in order:
            w = _member_weight(pool[j])
            picked: List[int] = []
            for _ in range(replicas):
                h = min((h for h in range(n_hosts) if h not in picked),
                        key=lambda h: (load[h], h))
                picked.append(h)
                load[h] += w
            chosen[j] = tuple(picked)
        placements = [
            MemberPlacement(j, chosen[j], weight=_member_weight(pool[j]),
                            mesh_axes=(mesh_axes or {}).get(j))
            for j in range(len(pool))
        ]
        return cls(hosts, placements)

    @classmethod
    def round_robin(cls, n_members: int, n_hosts: int,
                    devices: Optional[Sequence] = None) -> "PlacementPlan":
        groups = (partition_devices(devices, n_hosts) if devices
                  else ((),) * n_hosts)
        hosts = [HostSpec(h, groups[h]) for h in range(n_hosts)]
        placements = [MemberPlacement(j, (j % n_hosts,))
                      for j in range(n_members)]
        return cls(hosts, placements)

    # -- queries --------------------------------------------------------
    @property
    def n_hosts(self) -> int:
        return len(self.hosts)

    @property
    def n_members(self) -> int:
        return len(self.placements)

    def members_on_host(self, host_id: int) -> List[int]:
        """Members with a replica placed on ``host_id`` (dead or alive)."""
        with self._lock:
            return [p.member_idx for p in self.placements if host_id in p.hosts]

    def primary_host(self, member_idx: int) -> Optional[int]:
        """The first *alive* replica host for a member, or None if every
        replica's host is dead (the member is unroutable)."""
        with self._lock:
            for h in self.placements[member_idx].hosts:
                if h not in self.dead_hosts:
                    return h
            return None

    def replica_host(self, member_idx: int,
                     avoid: Sequence[int] = ()) -> Optional[int]:
        """The first *alive* replica host not in ``avoid`` — the hedging
        target when the member's routed host straggles — or None when
        the member has no alternative."""
        skip = set(avoid)
        with self._lock:
            for h in self.placements[member_idx].hosts:
                if h not in skip and h not in self.dead_hosts:
                    return h
            return None

    def dead_members(self) -> List[int]:
        """Members with no surviving replica (a consistent snapshot: the
        plan cannot flip hosts mid-iteration)."""
        with self._lock:
            return [p.member_idx for p in self.placements
                    if all(h in self.dead_hosts for h in p.hosts)]

    def alive_members(self) -> List[int]:
        with self._lock:
            return [p.member_idx for p in self.placements
                    if any(h not in self.dead_hosts for h in p.hosts)]

    def alive_hosts(self) -> List[int]:
        with self._lock:
            return [h.host_id for h in self.hosts
                    if h.host_id not in self.dead_hosts]

    def under_replicated(self) -> List[int]:
        """Members whose *alive* replica count is below the plan's target —
        the set :meth:`rebalance` re-places after a host death."""
        with self._lock:
            return [p.member_idx for p in self.placements
                    if 0 < sum(h not in self.dead_hosts for h in p.hosts)
                    < self.target_replicas]

    def host_load(self) -> Dict[int, float]:
        """Σ placed member weight per host — what the greedy placer balances."""
        with self._lock:
            load = {h.host_id: 0.0 for h in self.hosts}
            for p in self.placements:
                for h in p.hosts:
                    load[h] += p.weight
            return load

    # -- state changes --------------------------------------------------
    def mark_host_dead(self, host_id: int) -> List[int]:
        """Flip one host dead; returns the members this *newly* leaves
        with no surviving replica (empty if every member placed there
        fails over to a replica on a surviving host)."""
        with self._lock:
            if host_id not in {h.host_id for h in self.hosts}:
                raise ValueError(f"unknown host {host_id}")
            before = set(self.dead_members())
            self.dead_hosts.add(host_id)
            return sorted(set(self.dead_members()) - before)

    def revive_host(self, host_id: int) -> List[int]:
        """Re-admit one recovered host; returns the members that were
        unroutable and regained a replica (the set the Scheduler stops
        pre-masking).  The caller (router maintenance) owns the probation
        window — the plan itself flips immediately."""
        with self._lock:
            if host_id not in {h.host_id for h in self.hosts}:
                raise ValueError(f"unknown host {host_id}")
            before = set(self.dead_members())
            self.dead_hosts.discard(host_id)
            return sorted(before - set(self.dead_members()))

    def rebalance(self) -> List[Tuple[int, int]]:
        """Restore replica redundancy lost to host deaths.

        Every under-replicated member (alive replicas < the plan's
        original replica target, but > 0 — fully dead members have
        nothing to copy a replica from) gains one new replica host: the
        least-loaded *alive* host not already holding it, ties toward
        the lower id — the same deterministic greedy rule as
        :meth:`auto`.  Returns the (member, new_host) moves, in member
        order.  A later revival of the original host can leave a member
        with more replicas than the target; extra redundancy is kept,
        never pruned."""
        with self._lock:
            load = self.host_load()
            moves: List[Tuple[int, int]] = []
            for j in self.under_replicated():
                p = self.placements[j]
                candidates = [h for h in self.alive_hosts() if h not in p.hosts]
                if not candidates:
                    continue  # every alive host already holds a replica
                h = min(candidates, key=lambda h: (load[h], h))
                self.placements[j] = dataclasses.replace(
                    p, hosts=p.hosts + (h,))
                load[h] += p.weight
                moves.append((j, h))
            return moves

    def revive(self) -> None:
        """Bring every host back (scenario replays start from a clean fleet)."""
        with self._lock:
            self.dead_hosts.clear()

    # -- meshes ---------------------------------------------------------
    def host_mesh(self, host_id: int):
        """The per-host jax Mesh, or None for a logical-only host."""
        spec = next(h for h in self.hosts if h.host_id == host_id)
        if not spec.devices:
            return None
        mesh = self._mesh_cache.get(host_id)
        if mesh is None:
            mesh = self._mesh_cache[host_id] = host_mesh(spec.devices)
        return mesh

    def member_rules(self, member_idx: int,
                     host: Optional[int] = None) -> Optional[AxisRules]:
        """AxisRules for a member's generation on its primary host (or an
        explicitly pinned ``host`` — fan-out resolves routing at planning
        time and must not re-read it at execution time), with the
        member's per-placement axis overrides applied; None when the
        plan is logical-only or the member is unroutable."""
        h = self.primary_host(member_idx) if host is None else host
        if h is None:
            return None
        mesh = self.host_mesh(h)
        if mesh is None:
            return None
        return default_axis_rules(mesh, self.placements[member_idx].mesh_axes)

    # -- debugging ------------------------------------------------------
    def describe(self) -> str:
        lines = []
        for h in self.hosts:
            state = "DEAD" if h.host_id in self.dead_hosts else "up"
            members = self.members_on_host(h.host_id)
            lines.append(f"host {h.host_id} [{state}] "
                         f"devices={h.n_devices} members={members}")
        return "\n".join(lines)
