"""Member-to-host placement over a sharded device fleet.

A :class:`PlacementPlan` assigns every pool member to one or more
logical *hosts* — contiguous device groups carved out of the fleet by
:func:`repro.sharding.api.partition_devices` — and knows how to stand up
the per-host mesh (:func:`repro.sharding.api.host_mesh`) and the
per-member :class:`~repro.sharding.api.AxisRules` that member's
generation should run under.  Plans are *logical first*: a plan built
without real devices (single-device CI, the behavioural simulator) has
the same routing semantics as one spanning an 8-host forced-device
mesh, so every cluster test runs anywhere.

Two constructors cover the common cases:

* :meth:`PlacementPlan.auto` — the greedy cost/VRAM-balanced placer:
  members are placed heaviest-first onto the host with the least
  accumulated weight (bf16 parameter bytes, which under Kaplan costs is
  also proportional to per-token FLOPs — balancing one balances both),
  with replicas forced onto distinct hosts so a single host failure
  never kills a replicated member.
* :meth:`PlacementPlan.round_robin` — member *i* on host ``i % n`` (the
  permutation-property tests sweep arbitrary assignments on top).

Host death is a plan-level state change: :meth:`mark_host_dead` flips
the host and returns the members left with no surviving replica — the
set the Scheduler masks out of the knapsack re-solve (see
:class:`~repro.serve.backends.HostFailure`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

from repro.sharding.api import (
    AxisRules,
    MeshAxes,
    default_axis_rules,
    host_mesh,
    partition_devices,
)


@dataclasses.dataclass(frozen=True)
class HostSpec:
    """One logical host: an id plus the devices it owns (possibly none —
    a logical-only plan routes identically without touching jax)."""

    host_id: int
    devices: Tuple = ()

    @property
    def n_devices(self) -> int:
        return len(self.devices)


@dataclasses.dataclass(frozen=True)
class MemberPlacement:
    """Where one pool member runs.

    ``hosts`` lists replica hosts in preference order (primary first);
    ``mesh_axes`` optionally overrides the logical→mesh axis rules for
    this member's generation (e.g. a big member sharding ``mlp`` over the
    whole host while a small one replicates); ``weight`` is the placer's
    balance metric (bf16 parameter bytes)."""

    member_idx: int
    hosts: Tuple[int, ...]
    weight: float = 0.0
    mesh_axes: Optional[Mapping[str, MeshAxes]] = None


def _member_weight(spec) -> float:
    """bf16 parameter bytes — the VRAM footprint, and (×2 FLOPs/param/token
    under Kaplan) the cost proxy the greedy placer balances."""
    params_b = getattr(spec, "params_b", None)
    if params_b is None:
        return 1.0
    return float(params_b) * 1e9 * 2.0


class PlacementPlan:
    """Assignment of pool members onto logical hosts (with optional meshes)."""

    def __init__(self, hosts: Sequence[HostSpec],
                 placements: Sequence[MemberPlacement]):
        if not hosts:
            raise ValueError("a placement plan needs at least one host")
        self.hosts = list(hosts)
        self.placements = list(placements)
        host_ids = {h.host_id for h in self.hosts}
        if len(host_ids) != len(self.hosts):
            raise ValueError("duplicate host ids in plan")
        for p in self.placements:
            if not p.hosts:
                raise ValueError(f"member {p.member_idx} placed on no host")
            missing = [h for h in p.hosts if h not in host_ids]
            if missing:
                raise ValueError(
                    f"member {p.member_idx} placed on unknown hosts {missing}")
            if len(set(p.hosts)) != len(p.hosts):
                raise ValueError(
                    f"member {p.member_idx} has duplicate replica hosts")
        self.dead_hosts: Set[int] = set()
        self._mesh_cache: Dict[int, object] = {}

    # -- constructors ---------------------------------------------------
    @classmethod
    def auto(cls, pool: Sequence, n_hosts: int, replicas: int = 1,
             devices: Optional[Sequence] = None,
             mesh_axes: Optional[Mapping[int, Mapping[str, MeshAxes]]] = None,
             ) -> "PlacementPlan":
        """Greedy balanced placement of ``pool`` over ``n_hosts`` hosts.

        Members are placed heaviest-first; each replica goes to the
        least-loaded host not already holding one (load = Σ placed member
        weight).  Ties break toward the lower host id, so the plan is a
        pure function of the pool — two processes building it agree
        without coordination."""
        if n_hosts < 1:
            raise ValueError("n_hosts must be >= 1")
        if not 1 <= replicas <= n_hosts:
            raise ValueError(f"replicas={replicas} must be in [1, {n_hosts}]")
        groups = (partition_devices(devices, n_hosts) if devices
                  else ((),) * n_hosts)
        hosts = [HostSpec(h, groups[h]) for h in range(n_hosts)]
        load = [0.0] * n_hosts
        order = sorted(range(len(pool)),
                       key=lambda j: (-_member_weight(pool[j]), j))
        chosen: Dict[int, Tuple[int, ...]] = {}
        for j in order:
            w = _member_weight(pool[j])
            picked: List[int] = []
            for _ in range(replicas):
                h = min((h for h in range(n_hosts) if h not in picked),
                        key=lambda h: (load[h], h))
                picked.append(h)
                load[h] += w
            chosen[j] = tuple(picked)
        placements = [
            MemberPlacement(j, chosen[j], weight=_member_weight(pool[j]),
                            mesh_axes=(mesh_axes or {}).get(j))
            for j in range(len(pool))
        ]
        return cls(hosts, placements)

    @classmethod
    def round_robin(cls, n_members: int, n_hosts: int,
                    devices: Optional[Sequence] = None) -> "PlacementPlan":
        groups = (partition_devices(devices, n_hosts) if devices
                  else ((),) * n_hosts)
        hosts = [HostSpec(h, groups[h]) for h in range(n_hosts)]
        placements = [MemberPlacement(j, (j % n_hosts,))
                      for j in range(n_members)]
        return cls(hosts, placements)

    # -- queries --------------------------------------------------------
    @property
    def n_hosts(self) -> int:
        return len(self.hosts)

    @property
    def n_members(self) -> int:
        return len(self.placements)

    def members_on_host(self, host_id: int) -> List[int]:
        """Members with a replica placed on ``host_id`` (dead or alive)."""
        return [p.member_idx for p in self.placements if host_id in p.hosts]

    def primary_host(self, member_idx: int) -> Optional[int]:
        """The first *alive* replica host for a member, or None if every
        replica's host is dead (the member is unroutable)."""
        for h in self.placements[member_idx].hosts:
            if h not in self.dead_hosts:
                return h
        return None

    def dead_members(self) -> List[int]:
        """Members with no surviving replica."""
        return [p.member_idx for p in self.placements
                if all(h in self.dead_hosts for h in p.hosts)]

    def alive_members(self) -> List[int]:
        return [p.member_idx for p in self.placements
                if any(h not in self.dead_hosts for h in p.hosts)]

    def host_load(self) -> Dict[int, float]:
        """Σ placed member weight per host — what the greedy placer balances."""
        load = {h.host_id: 0.0 for h in self.hosts}
        for p in self.placements:
            for h in p.hosts:
                load[h] += p.weight
        return load

    # -- state changes --------------------------------------------------
    def mark_host_dead(self, host_id: int) -> List[int]:
        """Flip one host dead; returns the members this *newly* leaves
        with no surviving replica (empty if every member placed there
        fails over to a replica on a surviving host)."""
        if host_id not in {h.host_id for h in self.hosts}:
            raise ValueError(f"unknown host {host_id}")
        before = set(self.dead_members())
        self.dead_hosts.add(host_id)
        return sorted(set(self.dead_members()) - before)

    def revive(self) -> None:
        """Bring every host back (scenario replays start from a clean fleet)."""
        self.dead_hosts.clear()

    # -- meshes ---------------------------------------------------------
    def host_mesh(self, host_id: int):
        """The per-host jax Mesh, or None for a logical-only host."""
        spec = next(h for h in self.hosts if h.host_id == host_id)
        if not spec.devices:
            return None
        mesh = self._mesh_cache.get(host_id)
        if mesh is None:
            mesh = self._mesh_cache[host_id] = host_mesh(spec.devices)
        return mesh

    def member_rules(self, member_idx: int) -> Optional[AxisRules]:
        """AxisRules for a member's generation on its primary host, with
        the member's per-placement axis overrides applied; None when the
        plan is logical-only or the member is unroutable."""
        h = self.primary_host(member_idx)
        if h is None:
            return None
        mesh = self.host_mesh(h)
        if mesh is None:
            return None
        return default_axis_rules(mesh, self.placements[member_idx].mesh_axes)

    # -- debugging ------------------------------------------------------
    def describe(self) -> str:
        lines = []
        for h in self.hosts:
            state = "DEAD" if h.host_id in self.dead_hosts else "up"
            members = self.members_on_host(h.host_id)
            lines.append(f"host {h.host_id} [{state}] "
                         f"devices={h.n_devices} members={members}")
        return "\n".join(lines)
