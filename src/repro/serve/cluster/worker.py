"""Thread-backed batch dispatch with a bounded inbox, plus the per-host
executor fabric fan-out routing runs on.

:class:`DispatchWorker` decouples ``Scheduler.submit`` from batch
service: the scheduler forms batches on the caller's thread (cheap,
deterministic) and enqueues them here; a single worker thread pops jobs
FIFO and runs the serve function (engine call + hedged retry).  One
worker thread — not a pool — is deliberate: FIFO execution keeps batch
service order identical to the synchronous path, which is what makes
async traces byte-identical to ``sync=True`` traces (the determinism
the scenario suite pins).

Backpressure is the bounded inbox: :meth:`try_submit` fails fast when
the queue is at capacity (the Scheduler turns that into an
admission-control shed with reason ``backpressure``), while
:meth:`submit` blocks the producer — the no-admission fallback, where
slowing the caller is the only brake left.

:class:`HostExecutor` / :class:`HostExecutorPool` are the layer *below*
the dispatch worker: one bounded-queue worker thread per live placement
host, so a batch's per-host member shards generate concurrently
(``ClusterRouter(fanout=True)``).  The pool is dynamic — a host's
executor is retired when the host dies and lazily respawned after the
host is revived — which is what turns the cluster layer from a routing
table into a self-healing executor fabric.  Concurrency here never
touches ordering semantics: the batch-level serve joins every shard
before returning, so the DispatchWorker above still sees one batch at a
time.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, List, Optional


class InboxFull(RuntimeError):
    """The worker's bounded inbox is at capacity (backpressure signal)."""


_STOP = object()


class DispatchWorker:
    """Single-threaded FIFO executor with a bounded inbox."""

    def __init__(self, fn: Callable, capacity: int = 64,
                 name: str = "dispatch-worker",
                 on_orphan: Optional[Callable] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self._fn = fn
        self.capacity = capacity
        self._inbox: "queue.Queue" = queue.Queue(maxsize=capacity)
        self._closed = False
        self.processed = 0
        self.max_depth = 0
        self.orphaned = 0
        self._on_orphan = on_orphan
        # serialises the closed-check-then-enqueue step against close()
        # flipping the flag, so no producer can enqueue after the orphan
        # drain has run
        self._submit_lock = threading.Lock()
        self.errors: List[BaseException] = []  # post-resolution diagnostics
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=name)
        self._thread.start()

    # -- producer side ---------------------------------------------------
    def submit(self, job) -> None:
        """Enqueue a job, blocking while the inbox is full."""
        with self._submit_lock:
            if self._closed:
                raise RuntimeError("worker is closed")
            # blocking put is safe under the lock: the worker thread
            # drains independently, so space always frees up
            self._inbox.put(job)
        self.max_depth = max(self.max_depth, self._inbox.qsize())

    def try_submit(self, job) -> None:
        """Enqueue a job or raise :class:`InboxFull` without blocking."""
        with self._submit_lock:
            if self._closed:
                raise RuntimeError("worker is closed")
            try:
                self._inbox.put_nowait(job)
            except queue.Full:
                raise InboxFull(
                    f"dispatch inbox at capacity ({self.capacity})") from None
        self.max_depth = max(self.max_depth, self._inbox.qsize())

    def full(self) -> bool:
        return self._inbox.qsize() >= self.capacity

    @property
    def depth(self) -> int:
        """Jobs enqueued or in service right now."""
        return self._inbox.unfinished_tasks

    def join(self) -> None:
        """Block until every enqueued job has finished service."""
        self._inbox.join()

    def close(self) -> None:
        """Drain, stop the thread, and reject further submits.

        An accepted job is never silently dropped: ``try_submit`` can
        pass the closed check and enqueue *behind* the stop sentinel
        (the submit/close race), so after the thread exits any jobs
        left in the inbox are handed to ``on_orphan`` — the owner
        resolves their futures (the Scheduler fails them with the same
        "worker is closed" error a losing ``try_submit`` would see) so
        no accepted job's future can hang."""
        with self._submit_lock:
            if self._closed:
                return
            self._closed = True
        self._inbox.put(_STOP)
        self._thread.join()
        while True:  # jobs that raced past the closed check land here
            try:
                job = self._inbox.get_nowait()
            except queue.Empty:
                break
            try:
                if job is not _STOP:
                    self.orphaned += 1
                    if self._on_orphan is not None:
                        self._on_orphan(job)
            finally:
                self._inbox.task_done()

    # -- worker side -----------------------------------------------------
    def _loop(self) -> None:
        while True:
            job = self._inbox.get()
            try:
                if job is _STOP:
                    return
                try:
                    self._fn(job)
                except BaseException as exc:  # futures already resolved by fn
                    self.errors.append(exc)
                else:
                    self.processed += 1
            finally:
                self._inbox.task_done()


class CancelledShard(RuntimeError):
    """Raised by :meth:`ShardFuture.result` when the shard was cancelled
    before its executor ran it."""


class ShardFuture:
    """Resolution handle for one host shard submitted to a HostExecutor."""

    def __init__(self):
        self._done = threading.Event()
        self._result = None
        self._error: Optional[BaseException] = None
        self._cancelled = False

    def set_result(self, result) -> None:
        self._result = result
        self._done.set()

    def set_error(self, error: BaseException) -> None:
        self._error = error
        self._done.set()

    def done(self) -> bool:
        return self._done.is_set()

    def cancel(self) -> bool:
        """Best-effort cancellation (the deadline/hedging hook).

        Returns False when the shard already resolved.  A queued shard
        is dropped by its executor (``result()`` then raises
        :class:`CancelledShard`); a shard already *running* completes
        normally — the hedger tolerates that by letting the earliest
        completion win."""
        if self._done.is_set():
            return False
        self._cancelled = True
        return True

    def cancelled(self) -> bool:
        return self._cancelled

    def result(self, timeout: Optional[float] = None):
        if not self._done.wait(timeout):
            raise TimeoutError(f"shard not served within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result


class HostExecutor:
    """One worker thread serving a single placement host's shards, FIFO.

    Shards from the same batch run concurrently *across* executors and
    sequentially *within* one — which is exactly the determinism the
    fan-out router needs: a host's dispatch order (and therefore its
    injected-failure schedule) is identical to sequential routing."""

    def __init__(self, host_id: int, capacity: int = 8):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.host_id = host_id
        self.capacity = capacity
        self._inbox: "queue.Queue" = queue.Queue(maxsize=capacity)
        self._closed = False
        self.processed = 0
        self._thread = threading.Thread(
            target=self._loop, daemon=True, name=f"host-{host_id}-executor")
        self._thread.start()

    def submit(self, fn: Callable[[], object]) -> ShardFuture:
        """Enqueue one shard thunk; blocks while the bounded queue is full."""
        if self._closed:
            raise RuntimeError(f"host {self.host_id} executor is closed")
        future = ShardFuture()
        self._inbox.put((fn, future))
        return future

    def close(self) -> None:
        """Drain queued shards, stop the thread, reject further submits."""
        if self._closed:
            return
        self._closed = True
        self._inbox.put(_STOP)
        self._thread.join()

    def _loop(self) -> None:
        while True:
            job = self._inbox.get()
            try:
                if job is _STOP:
                    return
                fn, future = job
                if future.cancelled():
                    future.set_error(CancelledShard(
                        f"shard cancelled before host {self.host_id} ran it"))
                    continue
                try:
                    future.set_result(fn())
                except BaseException as exc:
                    future.set_error(exc)
                else:
                    self.processed += 1
            finally:
                self._inbox.task_done()


class HostExecutorPool:
    """Dynamic pool of per-host executors: one live thread per live host.

    Executors spawn lazily on first submit to a host and are *retired*
    (drained and joined) when the router marks the host dead — a revived
    host simply gets a fresh executor on its next shard, so revival costs
    one thread spawn and no coordination."""

    def __init__(self, capacity: int = 8):
        self.capacity = capacity
        self._executors: Dict[int, HostExecutor] = {}
        self._lock = threading.Lock()
        self._closed = False
        self.spawned = 0
        self.retired = 0

    def executor(self, host_id: int) -> HostExecutor:
        with self._lock:
            if self._closed:
                # lazy respawn after close() would leak a thread nothing
                # will ever join — refuse loudly instead
                raise RuntimeError("executor pool is closed")
            ex = self._executors.get(host_id)
            if ex is None:
                ex = self._executors[host_id] = HostExecutor(
                    host_id, capacity=self.capacity)
                self.spawned += 1
            return ex

    def submit(self, host_id: int, fn: Callable[[], object]) -> ShardFuture:
        return self.executor(host_id).submit(fn)

    def retire(self, host_id: int) -> None:
        """Drain and stop a dead host's executor (no-op if never spawned)."""
        with self._lock:
            ex = self._executors.pop(host_id, None)
            if ex is not None:
                self.retired += 1
        if ex is not None:
            ex.close()

    def live_hosts(self) -> List[int]:
        with self._lock:
            return sorted(self._executors)

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Stop every executor; idempotent; further submits raise."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            executors = list(self._executors.values())
            self._executors.clear()
        for ex in executors:
            ex.close()
