"""Thread-backed batch dispatch with a bounded inbox.

:class:`DispatchWorker` decouples ``Scheduler.submit`` from batch
service: the scheduler forms batches on the caller's thread (cheap,
deterministic) and enqueues them here; a single worker thread pops jobs
FIFO and runs the serve function (engine call + hedged retry).  One
worker thread — not a pool — is deliberate: FIFO execution keeps batch
service order identical to the synchronous path, which is what makes
async traces byte-identical to ``sync=True`` traces (the determinism
the scenario suite pins).

Backpressure is the bounded inbox: :meth:`try_submit` fails fast when
the queue is at capacity (the Scheduler turns that into an
admission-control shed with reason ``backpressure``), while
:meth:`submit` blocks the producer — the no-admission fallback, where
slowing the caller is the only brake left.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, List


class InboxFull(RuntimeError):
    """The worker's bounded inbox is at capacity (backpressure signal)."""


_STOP = object()


class DispatchWorker:
    """Single-threaded FIFO executor with a bounded inbox."""

    def __init__(self, fn: Callable, capacity: int = 64,
                 name: str = "dispatch-worker"):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self._fn = fn
        self.capacity = capacity
        self._inbox: "queue.Queue" = queue.Queue(maxsize=capacity)
        self._closed = False
        self.processed = 0
        self.max_depth = 0
        self.errors: List[BaseException] = []  # post-resolution diagnostics
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=name)
        self._thread.start()

    # -- producer side ---------------------------------------------------
    def submit(self, job) -> None:
        """Enqueue a job, blocking while the inbox is full."""
        if self._closed:
            raise RuntimeError("worker is closed")
        self._inbox.put(job)
        self.max_depth = max(self.max_depth, self._inbox.qsize())

    def try_submit(self, job) -> None:
        """Enqueue a job or raise :class:`InboxFull` without blocking."""
        if self._closed:
            raise RuntimeError("worker is closed")
        try:
            self._inbox.put_nowait(job)
        except queue.Full:
            raise InboxFull(
                f"dispatch inbox at capacity ({self.capacity})") from None
        self.max_depth = max(self.max_depth, self._inbox.qsize())

    def full(self) -> bool:
        return self._inbox.qsize() >= self.capacity

    @property
    def depth(self) -> int:
        """Jobs enqueued or in service right now."""
        return self._inbox.unfinished_tasks

    def join(self) -> None:
        """Block until every enqueued job has finished service."""
        self._inbox.join()

    def close(self) -> None:
        """Drain, stop the thread, and reject further submits."""
        if self._closed:
            return
        self._closed = True
        self._inbox.put(_STOP)
        self._thread.join()

    # -- worker side -----------------------------------------------------
    def _loop(self) -> None:
        while True:
            job = self._inbox.get()
            try:
                if job is _STOP:
                    return
                try:
                    self._fn(job)
                except BaseException as exc:  # futures already resolved by fn
                    self.errors.append(exc)
                else:
                    self.processed += 1
            finally:
                self._inbox.task_done()
