"""Pluggable member backends: how a selected pool member produces text.

The engine is backend-agnostic: anything satisfying the
:class:`MemberBackend` protocol can serve a pool.  Two implementations
ship with the repro:

* :class:`SimBackend` — the behavioural simulator (DESIGN.md §3).  The
  RNG is derived per ``(seed, member, query)``, so a member's response to
  a query is identical whether it arrives in a 400-row offline batch or
  as a single online request — the property the Scheduler-equivalence
  guarantee rests on.
* :class:`LiveLMBackend` — real tiny JAX decoder LMs, dispatched through
  the bucketed static-shape fast path (:mod:`repro.serve.dispatch`) so
  steady-state traffic compiles each generate bucket once and reuses its
  donated decode cache.

``max_new_tokens`` may be one int for the whole batch or a per-record
sequence: backends OWN truncation and must consume at most the row's
token cap per response (``TOKENIZER.decode_capped`` — the cut never
fabricates replacement characters, so valid-UTF-8 responses re-encode to
<= cap tokens; a live LM emitting genuinely invalid interior bytes can
still decode to U+FFFD, which is content, not cap overflow).  The engine
never re-tokenizes responses to enforce the cap.  The cap must not
depend on which other rows share the micro-batch (greedy decoding is
prefix-stable, so generating a member batch at the rows' max length and
slicing each row to its own cap equals generating each row alone at its
own cap).

This replaces the ``live_members is None`` branching that used to live
inside ``EnsembleServer._generate_member``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, List, Protocol, Sequence, Tuple, Union, runtime_checkable

import numpy as np

from repro.data.mixinstruct import PoolMemberSpec, Record, member_response
from repro.data.tokenizer import TOKENIZER
from repro.models.transformer import DecoderLM
from repro.serve.dispatch import BucketLadder, DecoderGenerateDispatcher
from repro.serve.generate import greedy_generate

MaxNewTokens = Union[int, Sequence[int]]


class MemberFailure(RuntimeError):
    """A single pool member's backend call failed mid-batch.

    The engine wraps any exception escaping ``backend.generate(j, ...)``
    in this type so the Scheduler can tell "one member is down" apart
    from "the engine itself is broken" and hedge: re-serve the batch with
    ``member_idx`` excluded instead of failing every sibling future."""

    def __init__(self, member_idx: int, cause: BaseException):
        super().__init__(f"pool member {member_idx} failed: {cause!r}")
        self.member_idx = member_idx
        self.cause = cause


class HostFailure(RuntimeError):
    """A whole placement host died mid-batch (cluster serving).

    Raised by a placement-aware backend (see
    :class:`repro.serve.cluster.ClusterRouter`) when a host-level fault
    takes down every member replica placed on ``host_id``.
    ``member_idxs`` lists the pool members left with *no* surviving
    replica — the set the Scheduler must mask out of the knapsack before
    re-serving the batch on the surviving placements.  Members that keep
    a live replica on another host are failed over inside the router and
    never appear here."""

    def __init__(self, host_id: int, member_idxs: Sequence[int] = (),
                 cause: BaseException | None = None):
        dead = ", ".join(str(j) for j in member_idxs) or "none"
        super().__init__(
            f"host {host_id} failed (members with no surviving replica: {dead})"
        )
        self.host_id = host_id
        self.member_idxs = tuple(member_idxs)
        self.cause = cause


@dataclasses.dataclass(frozen=True)
class GenerationCall:
    """One member's generation work within a batch: the rows that
    selected it plus their per-row token caps.  The engine hands the full
    batch's calls (member order) to a backend's optional
    ``generate_many(calls)`` hook — the seam fan-out routing
    (:class:`repro.serve.cluster.ClusterRouter`) plugs into — and falls
    back to one ``generate`` per call otherwise.  ``generate_many`` must
    return results in call order and raise :class:`MemberFailure` /
    :class:`HostFailure` with the same attribution the sequential loop
    would."""

    member_idx: int
    records: Tuple
    max_new_tokens: Tuple[int, ...]


def per_row_caps(max_new_tokens: MaxNewTokens, n_rows: int) -> List[int]:
    """Normalize an int-or-sequence token cap to one cap per row."""
    if isinstance(max_new_tokens, int):
        return [max_new_tokens] * n_rows
    caps = list(max_new_tokens)
    if len(caps) != n_rows:
        raise ValueError(f"{len(caps)} caps for {n_rows} records")
    return caps


@runtime_checkable
class MemberBackend(Protocol):
    """Generates pool-member responses for a micro-batch of queries."""

    def num_members(self) -> int:
        """Size of the pool this backend serves."""
        ...

    def generate(
        self,
        member_idx: int,
        records: Sequence[Record],
        max_new_tokens: MaxNewTokens,
    ) -> List[str]:
        """Member ``member_idx``'s response to each record, in order,
        each truncated to its row's token cap."""
        ...


def _query_rng(seed: int, member_idx: int, query: str) -> np.random.Generator:
    # errors="replace" mirrors the tokenizer: unpaired surrogates in an
    # online query must not crash the batch
    digest = hashlib.blake2b(
        query.encode("utf-8", errors="replace"), digest_size=8
    ).digest()
    return np.random.default_rng([seed, member_idx, int.from_bytes(digest, "little")])


@dataclasses.dataclass
class SimBackend:
    """Behavioural simulator over a pool of :class:`PoolMemberSpec`."""

    pool: Sequence[PoolMemberSpec]
    seed: int = 0

    def num_members(self) -> int:
        return len(self.pool)

    def generate(self, member_idx: int, records: Sequence[Record],
                 max_new_tokens: MaxNewTokens) -> List[str]:
        caps = per_row_caps(max_new_tokens, len(records))
        spec = self.pool[member_idx]
        out = []
        for r, cap in zip(records, caps):
            text = member_response(spec, r, _query_rng(self.seed, member_idx, r.query))
            # the simulator writes whole responses; one capped decode enforces
            # the row cap without fabricating U+FFFD at the cut point
            out.append(TOKENIZER.decode_capped(TOKENIZER.encode(text), cap))
        return out


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure wrapper around any :class:`MemberBackend`.

    ``failures`` maps a member index to the 0-based *call indices* (that
    member's n-th ``generate`` call, counted over the backend's lifetime)
    that raise instead of generating.  Because the schedule is keyed on
    call counts — not wall time — a traffic-simulator run that injects
    failures is exactly replayable: same seed, same arrivals, same calls,
    same faults.  Hedged retries consume call indices like any other
    call, so a member that fails call 2 can succeed on call 3.

    ``slow`` is the *grey-failure* schedule: call indices that complete
    normally but only after sleeping ``slow_s`` wall seconds — a member
    alive but straggling.  Slowness touches wall clock only, never the
    logical trace, so slowed runs stay byte-identical to fast ones;
    it exists to give shard deadlines and straggler hedging something
    real to race against."""

    inner: MemberBackend
    failures: Dict[int, Sequence[int]] = dataclasses.field(default_factory=dict)
    slow: Dict[int, Sequence[int]] = dataclasses.field(default_factory=dict)
    slow_s: float = 0.0
    calls: Dict[int, int] = dataclasses.field(default_factory=dict)
    slowed: int = 0  # grey-slow calls actually served (diagnostics)

    def num_members(self) -> int:
        return self.inner.num_members()

    def generate(self, member_idx: int, records: Sequence[Record],
                 max_new_tokens: MaxNewTokens) -> List[str]:
        k = self.calls.get(member_idx, 0)
        self.calls[member_idx] = k + 1
        if k in tuple(self.failures.get(member_idx, ())):
            raise RuntimeError(
                f"injected failure: member {member_idx}, call {k}"
            )
        if self.slow_s > 0 and k in tuple(self.slow.get(member_idx, ())):
            self.slowed += 1
            time.sleep(self.slow_s)
        return self.inner.generate(member_idx, records, max_new_tokens)

    # optional-protocol hooks forward to the wrapped backend
    def warm(self, shapes: Sequence) -> None:
        warm = getattr(self.inner, "warm", None)
        if callable(warm):
            warm(shapes)

    def compiles(self) -> int:
        compiles = getattr(self.inner, "compiles", None)
        return compiles() if callable(compiles) else 0

    def dead_members(self) -> List[int]:
        dead = getattr(self.inner, "dead_members", None)
        return dead() if callable(dead) else []

    # NOTE: generate_many is deliberately NOT forwarded — it would route
    # the engine's batch straight to the inner backend's fan-out and
    # bypass this injector's per-member schedules.  Maintenance hooks
    # are pure placement state and forward safely.
    def maintenance_pending(self, now: int) -> bool:
        pending = getattr(self.inner, "maintenance_pending", None)
        return pending(now) if callable(pending) else False

    def maintain(self, now: int) -> List[dict]:
        maintain = getattr(self.inner, "maintain", None)
        return maintain(now) if callable(maintain) else []


@dataclasses.dataclass
class LiveMember:
    """A real (tiny) decoder LM standing in for one pool member."""

    spec: PoolMemberSpec
    model: DecoderLM
    params: dict


@dataclasses.dataclass
class LiveLMBackend:
    """Live JAX LMs: prompt = ``<bos> query <sep>``, greedy decode.

    ``fast=True`` routes generation through one
    :class:`~repro.serve.dispatch.DecoderGenerateDispatcher` per member:
    micro-batches pad up to the bucket ladder, each bucket compiles once,
    and the decode cache is donated back to the same buffers call after
    call.  ``fast=False`` keeps the ad-hoc jit path (one compile per
    distinct shape)."""

    members: Sequence[LiveMember]
    max_query_len: int = 96
    fast: bool = True
    ladder: BucketLadder = dataclasses.field(default_factory=BucketLadder)
    _dispatchers: Dict[int, DecoderGenerateDispatcher] = dataclasses.field(
        default_factory=dict, repr=False
    )

    def num_members(self) -> int:
        return len(self.members)

    def _dispatcher(self, member_idx: int) -> DecoderGenerateDispatcher:
        d = self._dispatchers.get(member_idx)
        if d is None:
            lm = self.members[member_idx]
            d = self._dispatchers[member_idx] = DecoderGenerateDispatcher(
                lm.model, lm.params, ladder=self.ladder
            )
        return d

    def compiles(self) -> int:
        """Total live XLA compiles across member dispatchers.  Snapshot
        the dict first: fan-out shards lazily create dispatchers on host
        executor threads, and iterating a dict another thread is
        inserting into raises."""
        return sum(d.compiles for d in list(self._dispatchers.values()))

    def warm(self, shapes: Sequence) -> None:
        """Pre-compile the given (batch, max_new) buckets for every member."""
        if not self.fast:
            return  # the ad-hoc jit path has no buckets to warm
        for j in range(len(self.members)):
            self._dispatcher(j).warm(
                [(b, self.max_query_len, n) for b, n in shapes]
            )

    def generate(self, member_idx: int, records: Sequence[Record],
                 max_new_tokens: MaxNewTokens) -> List[str]:
        caps = per_row_caps(max_new_tokens, len(records))
        group_max = max(caps)
        prompts = [
            TOKENIZER.encode(r.query, bos=True) + [TOKENIZER.sep_id] for r in records
        ]
        batch = TOKENIZER.pad_batch(prompts, self.max_query_len)
        if self.fast:
            out = self._dispatcher(member_idx)(batch, group_max)
        else:
            lm = self.members[member_idx]
            out = greedy_generate(lm.model, lm.params, batch, max_new=group_max)
        # slice token ids to the row cap BEFORE the single decode — no
        # decode->encode->decode round trip per row; decode_capped strips a
        # cut-induced partial UTF-8 char instead of inflating it to U+FFFD
        return [TOKENIZER.decode_capped(row, cap) for row, cap in zip(out, caps)]
