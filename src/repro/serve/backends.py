"""Pluggable member backends: how a selected pool member produces text.

The engine is backend-agnostic: anything satisfying the
:class:`MemberBackend` protocol can serve a pool.  Two implementations
ship with the repro:

* :class:`SimBackend` — the behavioural simulator (DESIGN.md §3).  The
  RNG is derived per ``(seed, member, query)``, so a member's response to
  a query is identical whether it arrives in a 400-row offline batch or
  as a single online request — the property the Scheduler-equivalence
  guarantee rests on.
* :class:`LiveLMBackend` — real tiny JAX decoder LMs via
  ``greedy_generate``.

This replaces the ``live_members is None`` branching that used to live
inside ``EnsembleServer._generate_member``.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.data.mixinstruct import PoolMemberSpec, Record, member_response
from repro.data.tokenizer import TOKENIZER
from repro.models.transformer import DecoderLM
from repro.serve.generate import greedy_generate


@runtime_checkable
class MemberBackend(Protocol):
    """Generates pool-member responses for a micro-batch of queries."""

    def num_members(self) -> int:
        """Size of the pool this backend serves."""
        ...

    def generate(
        self,
        member_idx: int,
        records: Sequence[Record],
        max_new_tokens: int,
    ) -> List[str]:
        """Member ``member_idx``'s response to each record, in order."""
        ...


def _query_rng(seed: int, member_idx: int, query: str) -> np.random.Generator:
    # errors="replace" mirrors the tokenizer: unpaired surrogates in an
    # online query must not crash the batch
    digest = hashlib.blake2b(
        query.encode("utf-8", errors="replace"), digest_size=8
    ).digest()
    return np.random.default_rng([seed, member_idx, int.from_bytes(digest, "little")])


@dataclasses.dataclass
class SimBackend:
    """Behavioural simulator over a pool of :class:`PoolMemberSpec`."""

    pool: Sequence[PoolMemberSpec]
    seed: int = 0

    def num_members(self) -> int:
        return len(self.pool)

    def generate(self, member_idx: int, records: Sequence[Record],
                 max_new_tokens: int) -> List[str]:
        spec = self.pool[member_idx]
        return [
            member_response(spec, r, _query_rng(self.seed, member_idx, r.query))
            for r in records
        ]


@dataclasses.dataclass
class LiveMember:
    """A real (tiny) decoder LM standing in for one pool member."""

    spec: PoolMemberSpec
    model: DecoderLM
    params: dict


@dataclasses.dataclass
class LiveLMBackend:
    """Live JAX LMs: prompt = ``<bos> query <sep>``, greedy decode."""

    members: Sequence[LiveMember]
    max_query_len: int = 96

    def num_members(self) -> int:
        return len(self.members)

    def generate(self, member_idx: int, records: Sequence[Record],
                 max_new_tokens: int) -> List[str]:
        lm = self.members[member_idx]
        prompts = [
            TOKENIZER.encode(r.query, bos=True) + [TOKENIZER.sep_id] for r in records
        ]
        batch = TOKENIZER.pad_batch(prompts, self.max_query_len)
        out = greedy_generate(lm.model, lm.params, batch, max_new=max_new_tokens)
        return [TOKENIZER.decode(row) for row in out]
