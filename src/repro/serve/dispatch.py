"""Static-shape serving fast path: bucketed jit dispatch with donated caches.

Every distinct (batch, prompt length, max_new) triple hitting a jitted
generate function triggers a fresh XLA compile, so online traffic through
the admission Scheduler — whose micro-batches vary in size tick to tick —
recompiles on nearly every batch.  This module removes that tax:

* **Bucketing** — micro-batches are padded up to a small fixed ladder of
  shapes (:class:`BucketLadder`, powers-of-two by default).  Batch rows
  are padded by *replicating row 0* (generation is row-independent, so
  padding rows cannot perturb real rows); token axes are right-padded
  with ``pad_id`` (position -1 → masked out, pinned by
  ``test_generate_padded_equals_unpadded``).  Outputs are sliced back to
  the caller's true shape.
* **Jit caching** — one jitted callable per bucket, compiled on first
  use (or eagerly via :meth:`warm`) and reused forever after: steady
  traffic hits zero recompiles.  ``compiles`` exposes the live XLA
  compile count for tests and benchmarks.
* **Cache donation** — the KV/decode cache is a persistent per-bucket
  buffer threaded through the jitted call with ``donate_argnums``, so
  XLA writes the step-final cache back into the same HBM allocation:
  zero cache reallocations in steady state.  Stale state is neutralized
  by ``generate.reset_cache`` inside the jit (position slots → -1, SSM
  state → 0).  Donation is skipped automatically on backends that cannot
  alias buffers (CPU).

Adding a bucket = adding one rung to the relevant :class:`BucketLadder`
tuple (see README "Performance").
"""

from __future__ import annotations

import bisect
import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import TOKENIZER
from repro.models.encdec import EncDecLM
from repro.models.transformer import DecoderLM
from repro.serve.generate import (
    decoder_generate_with_cache,
    encdec_decode_step,
    encdec_generate_with_cache,
    encdec_prefill_with_cache,
)


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class BucketLadder:
    """The fixed shape set the fast path compiles for.

    Values bucket to the smallest rung >= value; values beyond the top
    rung fall back to the next power of two (a new bucket — compiled
    once, then cached like any other).  Rungs need not be powers of two:
    the defaults pin the repo's common prompt lengths (96 = max_query_len,
    512 = max_fusion_len) so the hot shapes pad by zero."""

    batch: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)
    new_tokens: Tuple[int, ...] = (8, 16, 32, 64, 128)
    prompt: Tuple[int, ...] = (32, 64, 96, 128, 256, 512)

    @staticmethod
    def _pick(value: int, rungs: Tuple[int, ...]) -> int:
        for r in rungs:
            if value <= r:
                return r
        return _next_pow2(value)

    def batch_bucket(self, b: int) -> int:
        return self._pick(b, self.batch)

    def floor_batch_rung(self, b: int) -> int:
        """Largest batch rung <= b, for batch *formation* (the Scheduler):
        dispatching exactly a rung's worth of requests means the padded
        batch equals the real batch — zero wasted rows.  Falls back to
        ``b`` itself when every rung is larger (the batch then pads up to
        ``batch_bucket(b)``, which is still a compiled-once bucket)."""
        best = 0
        for r in self.batch:
            if r <= b:
                best = r
        return best or b

    def new_bucket(self, n: int) -> int:
        return self._pick(n, self.new_tokens)

    def prompt_bucket(self, s: int) -> int:
        return self._pick(s, self.prompt)


def _donate_default() -> bool:
    # CPU cannot alias donated buffers (XLA warns and ignores); donation
    # only buys anything where HBM reuse is real.
    return jax.default_backend() in ("tpu", "gpu")


@dataclasses.dataclass
class _Entry:
    fn: object  # jitted (params, tokens, cache) -> (out_tokens, cache)
    cache: dict  # persistent per-bucket decode cache (donated each call)


class _BucketedGenerate:
    """Shared machinery: bucket lookup, padding, entry cache, stats."""

    def __init__(self, params: dict, pad_id: int, eos_id: int,
                 ladder: Optional[BucketLadder], donate: Optional[bool]):
        self.params = params
        self.pad_id = pad_id
        self.eos_id = eos_id
        self.ladder = ladder or BucketLadder()
        self.donate = _donate_default() if donate is None else donate
        self._entries: Dict[Tuple[int, int, int], _Entry] = {}
        self._built = 0  # bucket compiles (fallback compile metric)
        # one generate at a time per dispatcher: entry caches are donated
        # (consumed per call), so a caller-thread warm() racing the async
        # DispatchWorker's generate on the same bucket would hand XLA an
        # already-consumed buffer
        self._call_lock = threading.Lock()
        self.stats = {"calls": 0, "padded_rows": 0, "padded_tokens": 0,
                      "direct_calls": 0}

    # -- subclass hooks -------------------------------------------------
    def _build(self, bb: int, sb: int, nb: int) -> _Entry:
        raise NotImplementedError

    def _make_cache(self, bb: int, sb: int, nb: int) -> dict:
        """Fresh decode cache for a bucket (first build + post-failure rebuild)."""
        raise NotImplementedError

    def _direct(self, tokens: np.ndarray, max_new: int) -> np.ndarray:
        """Exact-shape ad-hoc jit path (no bucket entry, no cached cache)."""
        raise NotImplementedError

    # -- compile accounting ---------------------------------------------
    @property
    def compiles(self) -> int:
        """Live XLA compile count across all buckets.  Reads the jit cache
        size when jax exposes it (it also catches intra-bucket misses,
        e.g. weak-type churn); otherwise falls back to the dispatcher's
        own bucket-build counter rather than silently flattening to a
        constant.  The entry dict is snapshotted first — monitoring reads
        race bucket creation on fan-out host executor threads, and
        iterating a dict mid-insert raises."""
        sizes = [getattr(entry.fn, "_cache_size", None)
                 for entry in list(self._entries.values())]
        if all(callable(s) for s in sizes):
            return sum(s() for s in sizes)
        return self._built

    @property
    def buckets(self) -> List[Tuple[int, int, int]]:
        return sorted(self._entries)

    def _token_bucket(self, s: int) -> int:
        """Bucketed token-axis length.  Decoder prompts right-pad safely
        (pad positions are masked out of attention); the enc-dec encoder
        has no pad masking, so its subclass keeps the length verbatim."""
        return self.ladder.prompt_bucket(s)

    # -- dispatch --------------------------------------------------------
    def _entry(self, bb: int, sb: int, nb: int) -> _Entry:
        key = (bb, sb, nb)
        entry = self._entries.get(key)
        if entry is None:
            entry = self._entries[key] = self._build(bb, sb, nb)
            self._built += 1
        return entry

    def __call__(self, tokens: np.ndarray, max_new: int) -> np.ndarray:
        """tokens [B, S] right-padded -> generated tokens [B, max_new]."""
        b, s = tokens.shape
        if b > self.ladder.batch[-1]:
            # one-shot offline mega-batch (e.g. a 400-row Table-1 eval):
            # padding to the next pow2 would waste up to ~2x compute and pin
            # an oversized donated cache forever — use the exact shape and
            # let its buffers die with the call
            self.stats["calls"] += 1
            self.stats["direct_calls"] += 1
            return self._direct(tokens, max_new)
        bb = self.ladder.batch_bucket(b)
        sb = self._token_bucket(s)
        nb = self.ladder.new_bucket(max_new)
        padded = np.full((bb, sb), self.pad_id, np.int32)
        padded[:b, :s] = tokens
        if bb > b:
            padded[b:] = padded[0]  # replicate a real row; rows are independent
        with self._call_lock:
            entry = self._entry(bb, sb, nb)
            try:
                out, entry.cache = entry.fn(self.params, jnp.asarray(padded),
                                            entry.cache)
            except Exception:
                # with donation active the cache buffer may already be consumed
                # even though the call failed (e.g. a transient device OOM);
                # rebuild it so the bucket isn't poisoned for all later traffic
                entry.cache = self._make_cache(bb, sb, nb)
                raise
            self.stats["calls"] += 1
            self.stats["padded_rows"] += bb - b
            self.stats["padded_tokens"] += (sb - s) * b
        return np.asarray(out)[:b, :max_new]

    def warm(self, shapes: Iterable[Tuple[int, int, int]]) -> None:
        """Pre-compile buckets: shapes are (batch, token_len, max_new),
        where token_len is the *actual* prompt/encoder length traffic will
        present (callers know it: max_query_len / max_fusion_len) — a
        guessed length would warm a bucket real traffic never hits.  Runs
        a dummy generate per shape so the jit cache (not just an AOT
        artifact) is primed."""
        for b, s, max_new in shapes:
            dummy = np.full((b, s), self.pad_id, np.int32)
            dummy[:, 0] = TOKENIZER.bos_id
            self(dummy, max_new)


class DecoderGenerateDispatcher(_BucketedGenerate):
    """Bucketed, cache-donating front-end over a decoder LM's greedy loop."""

    def __init__(self, model: DecoderLM, params: dict,
                 pad_id: int = TOKENIZER.pad_id, eos_id: int = TOKENIZER.eos_id,
                 ladder: Optional[BucketLadder] = None,
                 donate: Optional[bool] = None):
        super().__init__(params, pad_id, eos_id, ladder, donate)
        self.model = model

    def _build(self, bb: int, sb: int, nb: int) -> _Entry:
        model, pad_id, eos_id = self.model, self.pad_id, self.eos_id

        def run(params, prompt, cache):
            return decoder_generate_with_cache(
                model, params, prompt, cache, nb, pad_id, eos_id
            )

        fn = jax.jit(run, donate_argnums=(2,) if self.donate else ())
        return _Entry(fn=fn, cache=self._make_cache(bb, sb, nb))

    def _make_cache(self, bb: int, sb: int, nb: int) -> dict:
        return self.model.init_cache(bb, sb + nb + self.model.cfg.frontend_tokens)

    def _direct(self, tokens: np.ndarray, max_new: int) -> np.ndarray:
        from repro.serve.generate import greedy_generate

        return greedy_generate(self.model, self.params, tokens, max_new=max_new,
                               pad_id=self.pad_id, eos_id=self.eos_id)


class EncDecGenerateDispatcher(_BucketedGenerate):
    """Bucketed, cache-donating front-end over an enc-dec greedy loop
    (the GEN-FUSER hot path — every served micro-batch ends here).

    Only batch and max_new bucket; the encoder length keys the bucket
    verbatim because this encoder embeds pads like real tokens (no pad
    masking), so padding the encoder axis would perturb real rows.  The
    engine always presents a fixed ``max_fusion_len`` encoder shape, so
    the length axis is already static in practice."""

    def __init__(self, model: EncDecLM, params: dict,
                 pad_id: int = TOKENIZER.pad_id, eos_id: int = TOKENIZER.eos_id,
                 bos_id: int = TOKENIZER.bos_id,
                 ladder: Optional[BucketLadder] = None,
                 donate: Optional[bool] = None):
        super().__init__(params, pad_id, eos_id, ladder, donate)
        self.model = model
        self.bos_id = bos_id

    def _token_bucket(self, s: int) -> int:
        return s  # encoder length is part of the key — never padded

    def _build(self, bb: int, sb: int, nb: int) -> _Entry:
        model, pad_id, eos_id, bos_id = self.model, self.pad_id, self.eos_id, self.bos_id

        def run(params, enc_tokens, cache):
            return encdec_generate_with_cache(
                model, params, enc_tokens, cache, nb, pad_id, eos_id, bos_id
            )

        fn = jax.jit(run, donate_argnums=(2,) if self.donate else ())
        return _Entry(fn=fn, cache=self._make_cache(bb, sb, nb))

    def _make_cache(self, bb: int, sb: int, nb: int) -> dict:
        return self.model.init_cache(bb, nb + 2, enc_seq=sb)

    def _direct(self, tokens: np.ndarray, max_new: int) -> np.ndarray:
        from repro.serve.generate import greedy_generate_encdec

        return greedy_generate_encdec(self.model, self.params, tokens,
                                      max_new=max_new, pad_id=self.pad_id,
                                      eos_id=self.eos_id, bos_id=self.bos_id)


# ---------------------------------------------------------------------------
# Token-level continuous batching: persistent in-flight decode state
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _StreamRow:
    """Host-side bookkeeping for one in-flight decode slot."""

    cap: int  # row's max_new budget (leave trigger)
    tokens: List[int]  # emitted so far (includes eos/pad emissions verbatim)
    on_token: Optional[Callable]  # (tokens_so_far) -> None, per emission
    on_done: Callable  # (tokens) -> None, once, at eviction
    on_error: Optional[Callable]  # (exc) -> None if the decode loop dies


@dataclasses.dataclass
class _JoinGroup:
    """One prefilled admission chunk waiting for free decode slots.

    Prefill already ran (disaggregated from decode): the group carries its
    rung-shaped first tokens / done flags / fresh cache rows, so admitting
    it into the in-flight batch is a single scatter, never a prompt pass."""

    size: int  # real rows
    jb: int  # prefill/join rung (>= size; padding rows scatter nowhere)
    tok0: jax.Array  # [jb]
    done0: np.ndarray  # [jb] host copy (immediate-eviction decisions)
    done0_dev: jax.Array  # [jb]
    cache: dict  # fresh cache rows, [L, jb, ...] leaves
    rows: List[_StreamRow]


class StreamingEncDecBatcher:
    """Persistent in-flight decode state for the enc-dec fuser: requests
    join and leave the batch at ladder rungs on *every decode step*, not at
    batch boundaries.

    The replacement for per-batch :class:`EncDecGenerateDispatcher` calls
    on the streaming path: instead of one jitted whole-generation per
    (batch, max_new) bucket, the batcher keeps ``capacity`` decode slots
    live on device — carry token, per-row position, done mask, and a
    donated KV/cross cache — and compiles exactly three jit families:

    * **prefill** (one per join rung ``jb``) — encoder forward + BOS step
      over a fresh rung-shaped cache, run at :meth:`submit` time so long
      prompts never stall the decode loop (prefill disaggregation;
      ``prefill_chunk`` bounds rows per prefill call);
    * **join** (one per rung) — scatters the prefilled rows into free
      slots of the persistent state; padding rows carry an out-of-bounds
      slot index and are dropped by the scatter, so the join is
      rung-shaped without ever touching an occupied slot.  A joining row
      fully overwrites its slot's cache rows — KV slots are recycled in
      place, with no stale-state leak;
    * **step** (exactly one, capacity-shaped) — one
      :func:`~repro.serve.generate.encdec_decode_step` over all slots.
      Vacant/finished slots decode ``pad`` into themselves; live rows are
      bit-identical to the batch-boundary path (row independence, pinned
      by the padding-invariance property).

    Completed rows (eos, or their ``cap`` emitted) are evicted between
    steps and their slots backfilled from the FIFO pending queue, so a
    request arriving mid-decode joins at the next step with **zero new
    compiles** once the rungs are warm.  All host state is guarded by one
    lock; :meth:`pump` may be driven from any thread."""

    def __init__(self, model: EncDecLM, params: dict, enc_seq: int,
                 capacity: int = 8, max_new_cap: Optional[int] = None,
                 pad_id: int = TOKENIZER.pad_id, eos_id: int = TOKENIZER.eos_id,
                 bos_id: int = TOKENIZER.bos_id,
                 ladder: Optional[BucketLadder] = None,
                 donate: Optional[bool] = None,
                 prefill_chunk: Optional[int] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.model = model
        self.params = params
        self.enc_seq = enc_seq
        self.ladder = ladder or BucketLadder()
        # capacity is a compiled shape; snap it to a rung so the step fn
        # matches the ladder the rest of the fast path speaks
        self.capacity = self.ladder.batch_bucket(capacity)
        self.max_new_cap = (self.ladder.new_tokens[-1] if max_new_cap is None
                            else max_new_cap)
        self.pad_id, self.eos_id, self.bos_id = pad_id, eos_id, bos_id
        self.donate = _donate_default() if donate is None else donate
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.prefill_chunk = prefill_chunk
        self._lock = threading.RLock()
        # persistent device state: one slot per in-flight row
        self._tok = jnp.full((self.capacity,), pad_id, jnp.int32)
        self._pos = jnp.zeros((self.capacity,), jnp.int32)
        self._done = jnp.ones((self.capacity,), bool)
        self._cache = model.init_cache(self.capacity, self.max_new_cap + 2,
                                       enc_seq=enc_seq)
        self._rows: List[Optional[_StreamRow]] = [None] * self.capacity
        self._free: List[int] = list(range(self.capacity))  # kept sorted
        self._pending: "deque[_JoinGroup]" = deque()
        self._prefill_fns: Dict[int, object] = {}
        self._join_fns: Dict[int, object] = {}
        self._step_fn = None
        self._built = 0
        self.stats = {"prefills": 0, "joins": 0, "steps": 0, "rows": 0,
                      "evicted": 0, "padded_rows": 0}
        # wall time per decode step, for time-to-first-token / per-step p99
        self.step_wall_s: List[float] = []

    # -- compile accounting ---------------------------------------------
    @property
    def compiles(self) -> int:
        """Live XLA compile count across the prefill/join/step families
        (same contract as :attr:`_BucketedGenerate.compiles`)."""
        fns = (list(self._prefill_fns.values()) + list(self._join_fns.values())
               + ([self._step_fn] if self._step_fn is not None else []))
        sizes = [getattr(fn, "_cache_size", None) for fn in fns]
        if fns and all(callable(s) for s in sizes):
            return sum(s() for s in sizes)
        return self._built

    @property
    def in_flight(self) -> int:
        with self._lock:
            return sum(r is not None for r in self._rows)

    @property
    def idle(self) -> bool:
        with self._lock:
            return not self._pending and all(r is None for r in self._rows)

    # -- jit families ----------------------------------------------------
    def _prefill(self, jb: int):
        fn = self._prefill_fns.get(jb)
        if fn is None:
            model = self.model
            eos_id, bos_id = self.eos_id, self.bos_id
            max_seq, enc_seq = self.max_new_cap + 2, self.enc_seq

            def run(params, enc_tokens):
                cache = model.init_cache(jb, max_seq, enc_seq=enc_seq)
                return encdec_prefill_with_cache(
                    model, params, enc_tokens, cache, eos_id, bos_id)

            fn = self._prefill_fns[jb] = jax.jit(run)
            self._built += 1
        return fn

    def _join(self, jb: int):
        fn = self._join_fns.get(jb)
        if fn is None:
            def run(tok, pos, done, cache, idx, tok0, done0, cache0):
                # padding rows carry idx == capacity: out of bounds, so the
                # scatter drops them — the join stays rung-shaped without a
                # per-size compile and without touching occupied slots
                tok = tok.at[idx].set(tok0, mode="drop")
                pos = pos.at[idx].set(1, mode="drop")
                done = done.at[idx].set(done0, mode="drop")
                cache = jax.tree.map(
                    lambda big, small: big.at[:, idx].set(small, mode="drop"),
                    cache, cache0)
                return tok, pos, done, cache

            fn = self._join_fns[jb] = jax.jit(
                run, donate_argnums=(0, 1, 2, 3) if self.donate else ())
            self._built += 1
        return fn

    def _step(self):
        if self._step_fn is None:
            model, pad_id, eos_id = self.model, self.pad_id, self.eos_id

            def run(params, tok, pos, done, cache):
                return encdec_decode_step(
                    model, params, tok, pos, done, cache, pad_id, eos_id)

            self._step_fn = jax.jit(
                run, donate_argnums=(1, 2, 3, 4) if self.donate else ())
            self._built += 1
        return self._step_fn

    def warm(self, join_sizes: Iterable[int]) -> None:
        """Pre-compile the prefill/join rungs traffic will hit plus the
        step body, without disturbing in-flight state: the warm join
        scatters every row to the out-of-bounds sentinel (a no-op write),
        and the warm step runs over the untouched state — vacant slots
        already decode inert pads."""
        with self._lock:
            for size in join_sizes:
                jb = self.ladder.batch_bucket(max(1, min(size, self.capacity)))
                enc = np.full((jb, self.enc_seq), self.pad_id, np.int32)
                enc[:, 0] = self.bos_id
                tok0, done0, cache0 = self._prefill(jb)(
                    self.params, jnp.asarray(enc))
                idx = jnp.full((jb,), self.capacity, jnp.int32)
                self._tok, self._pos, self._done, self._cache = self._join(jb)(
                    self._tok, self._pos, self._done, self._cache,
                    idx, tok0, jnp.ones_like(done0), cache0)
            emit, self._tok, self._pos, self._done, self._cache = self._step()(
                self.params, self._tok, self._pos, self._done, self._cache)
            del emit

    # -- admission -------------------------------------------------------
    def submit(self, enc_tokens: np.ndarray, caps: List[int],
               on_token: Optional[Callable] = None,
               on_done: Optional[Callable] = None,
               on_error: Optional[Callable] = None) -> None:
        """Prefill ``enc_tokens [B, enc_seq]`` now and queue the rows for
        the decode loop.  Per-row callbacks fire under the batcher lock:
        ``on_token(i, tokens_so_far)`` after every emission,
        ``on_done(i, tokens)`` once at eviction, ``on_error(i, exc)`` if
        the decode loop dies with the row in flight.  Rows whose prefill
        already finished them (BOS argmax == eos, or ``cap == 0``) settle
        immediately — they never occupy a slot."""
        b, se = enc_tokens.shape
        if se != self.enc_seq:
            raise ValueError(
                f"encoder length {se} != batcher enc_seq {self.enc_seq}")
        if len(caps) != b:
            raise ValueError("caps must have one entry per row")
        if max(caps, default=0) > self.max_new_cap:
            raise ValueError(
                f"row cap {max(caps)} exceeds max_new_cap {self.max_new_cap}")
        chunk = self.capacity
        if self.prefill_chunk is not None:
            chunk = min(chunk, self.prefill_chunk)
        with self._lock:
            for lo in range(0, b, chunk):
                hi = min(lo + chunk, b)
                self._submit_chunk(enc_tokens[lo:hi], caps[lo:hi], lo,
                                   on_token, on_done, on_error)
            self._admit_pending()

    def _submit_chunk(self, enc: np.ndarray, caps: List[int], base: int,
                      on_token, on_done, on_error) -> None:
        size = enc.shape[0]
        jb = self.ladder.batch_bucket(size)
        jb = min(jb, self.capacity) if jb > self.capacity else jb
        padded = np.full((jb, self.enc_seq), self.pad_id, np.int32)
        padded[:size] = enc
        if jb > size:
            padded[size:] = padded[0]  # replicate a real row (independence)
        tok0, done0_dev, cache0 = self._prefill(jb)(self.params,
                                                    jnp.asarray(padded))
        self.stats["prefills"] += 1
        self.stats["rows"] += size
        self.stats["padded_rows"] += jb - size
        rows = []
        for k in range(size):
            i = base + k
            rows.append(_StreamRow(
                cap=caps[k], tokens=[],
                on_token=(lambda t, _i=i: on_token(_i, t)) if on_token else None,
                on_done=(lambda t, _i=i: on_done(_i, t)) if on_done
                else (lambda t: None),
                on_error=(lambda e, _i=i: on_error(_i, e)) if on_error
                else None,
            ))
        self._pending.append(_JoinGroup(
            size=size, jb=jb, tok0=tok0, done0=np.asarray(done0_dev),
            done0_dev=done0_dev, cache=cache0, rows=rows))

    def _admit_pending(self) -> None:
        """FIFO-join pending groups while slots are free.  Strict FIFO (a
        large group at the head waits even if a smaller one behind it
        would fit) keeps join order — and therefore slot assignment and
        the completion trace — deterministic across dispatch modes."""
        while self._pending and len(self._free) >= self._pending[0].size:
            g = self._pending.popleft()
            slots = self._free[:g.size]
            del self._free[:g.size]
            idx = np.full((g.jb,), self.capacity, np.int32)  # padding -> OOB
            idx[:g.size] = slots
            self._tok, self._pos, self._done, self._cache = self._join(g.jb)(
                self._tok, self._pos, self._done, self._cache,
                jnp.asarray(idx), g.tok0, g.done0_dev, g.cache)
            self.stats["joins"] += 1
            for slot, row, finished in zip(slots, g.rows, g.done0[:g.size]):
                if finished or row.cap <= 0:
                    # BOS argmax hit eos (every emission would be pad) or a
                    # zero-token budget: settle now, recycle the slot
                    bisect.insort(self._free, slot)
                    self.stats["evicted"] += 1
                    row.on_done(list(row.tokens))
                else:
                    self._rows[slot] = row

    # -- the decode loop -------------------------------------------------
    def pump(self, steps: Optional[int] = None) -> int:
        """Run up to ``steps`` decode steps (``None`` = until drained),
        admitting pending joins before each step and evicting finished
        rows after it.  Returns the number of steps executed.  On a device
        error every in-flight and pending row fails through ``on_error``
        (the stream's failure semantics: the error surfaces at the
        consumer, not inside the loop)."""
        executed = 0
        with self._lock:
            try:
                while steps is None or executed < steps:
                    self._admit_pending()
                    if all(r is None for r in self._rows):
                        break
                    t0 = time.perf_counter()
                    emit, self._tok, self._pos, self._done, self._cache = (
                        self._step()(self.params, self._tok, self._pos,
                                     self._done, self._cache))
                    emit_h = np.asarray(emit)
                    done_h = np.asarray(self._done)
                    self.step_wall_s.append(time.perf_counter() - t0)
                    self.stats["steps"] += 1
                    executed += 1
                    for slot in range(self.capacity):
                        row = self._rows[slot]
                        if row is None:
                            continue
                        row.tokens.append(int(emit_h[slot]))
                        if row.on_token is not None:
                            row.on_token(list(row.tokens))
                        if done_h[slot] or len(row.tokens) >= row.cap:
                            # leave: every later emission would be pad, or
                            # the row's budget is spent — final text is
                            # already byte-complete
                            self._rows[slot] = None
                            bisect.insort(self._free, slot)
                            self.stats["evicted"] += 1
                            row.on_done(list(row.tokens))
            except Exception as exc:
                self._fail_all(exc)
                raise
        return executed

    def _fail_all(self, exc: BaseException) -> None:
        rows = [r for r in self._rows if r is not None]
        self._rows = [None] * self.capacity
        self._free = list(range(self.capacity))
        for g in self._pending:
            rows.extend(g.rows)
        self._pending.clear()
        # neutralize device state: vacant slots must decode inert pads
        self._tok = jnp.full((self.capacity,), self.pad_id, jnp.int32)
        self._pos = jnp.zeros((self.capacity,), jnp.int32)
        self._done = jnp.ones((self.capacity,), bool)
        for row in rows:
            if row.on_error is not None:
                row.on_error(exc)
