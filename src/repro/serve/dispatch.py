"""Static-shape serving fast path: bucketed jit dispatch with donated caches.

Every distinct (batch, prompt length, max_new) triple hitting a jitted
generate function triggers a fresh XLA compile, so online traffic through
the admission Scheduler — whose micro-batches vary in size tick to tick —
recompiles on nearly every batch.  This module removes that tax:

* **Bucketing** — micro-batches are padded up to a small fixed ladder of
  shapes (:class:`BucketLadder`, powers-of-two by default).  Batch rows
  are padded by *replicating row 0* (generation is row-independent, so
  padding rows cannot perturb real rows); token axes are right-padded
  with ``pad_id`` (position -1 → masked out, pinned by
  ``test_generate_padded_equals_unpadded``).  Outputs are sliced back to
  the caller's true shape.
* **Jit caching** — one jitted callable per bucket, compiled on first
  use (or eagerly via :meth:`warm`) and reused forever after: steady
  traffic hits zero recompiles.  ``compiles`` exposes the live XLA
  compile count for tests and benchmarks.
* **Cache donation** — the KV/decode cache is a persistent per-bucket
  buffer threaded through the jitted call with ``donate_argnums``, so
  XLA writes the step-final cache back into the same HBM allocation:
  zero cache reallocations in steady state.  Stale state is neutralized
  by ``generate.reset_cache`` inside the jit (position slots → -1, SSM
  state → 0).  Donation is skipped automatically on backends that cannot
  alias buffers (CPU).

Adding a bucket = adding one rung to the relevant :class:`BucketLadder`
tuple (see README "Performance").
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import TOKENIZER
from repro.models.encdec import EncDecLM
from repro.models.transformer import DecoderLM
from repro.serve.generate import (
    decoder_generate_with_cache,
    encdec_generate_with_cache,
)


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class BucketLadder:
    """The fixed shape set the fast path compiles for.

    Values bucket to the smallest rung >= value; values beyond the top
    rung fall back to the next power of two (a new bucket — compiled
    once, then cached like any other).  Rungs need not be powers of two:
    the defaults pin the repo's common prompt lengths (96 = max_query_len,
    512 = max_fusion_len) so the hot shapes pad by zero."""

    batch: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)
    new_tokens: Tuple[int, ...] = (8, 16, 32, 64, 128)
    prompt: Tuple[int, ...] = (32, 64, 96, 128, 256, 512)

    @staticmethod
    def _pick(value: int, rungs: Tuple[int, ...]) -> int:
        for r in rungs:
            if value <= r:
                return r
        return _next_pow2(value)

    def batch_bucket(self, b: int) -> int:
        return self._pick(b, self.batch)

    def floor_batch_rung(self, b: int) -> int:
        """Largest batch rung <= b, for batch *formation* (the Scheduler):
        dispatching exactly a rung's worth of requests means the padded
        batch equals the real batch — zero wasted rows.  Falls back to
        ``b`` itself when every rung is larger (the batch then pads up to
        ``batch_bucket(b)``, which is still a compiled-once bucket)."""
        best = 0
        for r in self.batch:
            if r <= b:
                best = r
        return best or b

    def new_bucket(self, n: int) -> int:
        return self._pick(n, self.new_tokens)

    def prompt_bucket(self, s: int) -> int:
        return self._pick(s, self.prompt)


def _donate_default() -> bool:
    # CPU cannot alias donated buffers (XLA warns and ignores); donation
    # only buys anything where HBM reuse is real.
    return jax.default_backend() in ("tpu", "gpu")


@dataclasses.dataclass
class _Entry:
    fn: object  # jitted (params, tokens, cache) -> (out_tokens, cache)
    cache: dict  # persistent per-bucket decode cache (donated each call)


class _BucketedGenerate:
    """Shared machinery: bucket lookup, padding, entry cache, stats."""

    def __init__(self, params: dict, pad_id: int, eos_id: int,
                 ladder: Optional[BucketLadder], donate: Optional[bool]):
        self.params = params
        self.pad_id = pad_id
        self.eos_id = eos_id
        self.ladder = ladder or BucketLadder()
        self.donate = _donate_default() if donate is None else donate
        self._entries: Dict[Tuple[int, int, int], _Entry] = {}
        self._built = 0  # bucket compiles (fallback compile metric)
        # one generate at a time per dispatcher: entry caches are donated
        # (consumed per call), so a caller-thread warm() racing the async
        # DispatchWorker's generate on the same bucket would hand XLA an
        # already-consumed buffer
        self._call_lock = threading.Lock()
        self.stats = {"calls": 0, "padded_rows": 0, "padded_tokens": 0,
                      "direct_calls": 0}

    # -- subclass hooks -------------------------------------------------
    def _build(self, bb: int, sb: int, nb: int) -> _Entry:
        raise NotImplementedError

    def _make_cache(self, bb: int, sb: int, nb: int) -> dict:
        """Fresh decode cache for a bucket (first build + post-failure rebuild)."""
        raise NotImplementedError

    def _direct(self, tokens: np.ndarray, max_new: int) -> np.ndarray:
        """Exact-shape ad-hoc jit path (no bucket entry, no cached cache)."""
        raise NotImplementedError

    # -- compile accounting ---------------------------------------------
    @property
    def compiles(self) -> int:
        """Live XLA compile count across all buckets.  Reads the jit cache
        size when jax exposes it (it also catches intra-bucket misses,
        e.g. weak-type churn); otherwise falls back to the dispatcher's
        own bucket-build counter rather than silently flattening to a
        constant.  The entry dict is snapshotted first — monitoring reads
        race bucket creation on fan-out host executor threads, and
        iterating a dict mid-insert raises."""
        sizes = [getattr(entry.fn, "_cache_size", None)
                 for entry in list(self._entries.values())]
        if all(callable(s) for s in sizes):
            return sum(s() for s in sizes)
        return self._built

    @property
    def buckets(self) -> List[Tuple[int, int, int]]:
        return sorted(self._entries)

    def _token_bucket(self, s: int) -> int:
        """Bucketed token-axis length.  Decoder prompts right-pad safely
        (pad positions are masked out of attention); the enc-dec encoder
        has no pad masking, so its subclass keeps the length verbatim."""
        return self.ladder.prompt_bucket(s)

    # -- dispatch --------------------------------------------------------
    def _entry(self, bb: int, sb: int, nb: int) -> _Entry:
        key = (bb, sb, nb)
        entry = self._entries.get(key)
        if entry is None:
            entry = self._entries[key] = self._build(bb, sb, nb)
            self._built += 1
        return entry

    def __call__(self, tokens: np.ndarray, max_new: int) -> np.ndarray:
        """tokens [B, S] right-padded -> generated tokens [B, max_new]."""
        b, s = tokens.shape
        if b > self.ladder.batch[-1]:
            # one-shot offline mega-batch (e.g. a 400-row Table-1 eval):
            # padding to the next pow2 would waste up to ~2x compute and pin
            # an oversized donated cache forever — use the exact shape and
            # let its buffers die with the call
            self.stats["calls"] += 1
            self.stats["direct_calls"] += 1
            return self._direct(tokens, max_new)
        bb = self.ladder.batch_bucket(b)
        sb = self._token_bucket(s)
        nb = self.ladder.new_bucket(max_new)
        padded = np.full((bb, sb), self.pad_id, np.int32)
        padded[:b, :s] = tokens
        if bb > b:
            padded[b:] = padded[0]  # replicate a real row; rows are independent
        with self._call_lock:
            entry = self._entry(bb, sb, nb)
            try:
                out, entry.cache = entry.fn(self.params, jnp.asarray(padded),
                                            entry.cache)
            except Exception:
                # with donation active the cache buffer may already be consumed
                # even though the call failed (e.g. a transient device OOM);
                # rebuild it so the bucket isn't poisoned for all later traffic
                entry.cache = self._make_cache(bb, sb, nb)
                raise
            self.stats["calls"] += 1
            self.stats["padded_rows"] += bb - b
            self.stats["padded_tokens"] += (sb - s) * b
        return np.asarray(out)[:b, :max_new]

    def warm(self, shapes: Iterable[Tuple[int, int, int]]) -> None:
        """Pre-compile buckets: shapes are (batch, token_len, max_new),
        where token_len is the *actual* prompt/encoder length traffic will
        present (callers know it: max_query_len / max_fusion_len) — a
        guessed length would warm a bucket real traffic never hits.  Runs
        a dummy generate per shape so the jit cache (not just an AOT
        artifact) is primed."""
        for b, s, max_new in shapes:
            dummy = np.full((b, s), self.pad_id, np.int32)
            dummy[:, 0] = TOKENIZER.bos_id
            self(dummy, max_new)


class DecoderGenerateDispatcher(_BucketedGenerate):
    """Bucketed, cache-donating front-end over a decoder LM's greedy loop."""

    def __init__(self, model: DecoderLM, params: dict,
                 pad_id: int = TOKENIZER.pad_id, eos_id: int = TOKENIZER.eos_id,
                 ladder: Optional[BucketLadder] = None,
                 donate: Optional[bool] = None):
        super().__init__(params, pad_id, eos_id, ladder, donate)
        self.model = model

    def _build(self, bb: int, sb: int, nb: int) -> _Entry:
        model, pad_id, eos_id = self.model, self.pad_id, self.eos_id

        def run(params, prompt, cache):
            return decoder_generate_with_cache(
                model, params, prompt, cache, nb, pad_id, eos_id
            )

        fn = jax.jit(run, donate_argnums=(2,) if self.donate else ())
        return _Entry(fn=fn, cache=self._make_cache(bb, sb, nb))

    def _make_cache(self, bb: int, sb: int, nb: int) -> dict:
        return self.model.init_cache(bb, sb + nb + self.model.cfg.frontend_tokens)

    def _direct(self, tokens: np.ndarray, max_new: int) -> np.ndarray:
        from repro.serve.generate import greedy_generate

        return greedy_generate(self.model, self.params, tokens, max_new=max_new,
                               pad_id=self.pad_id, eos_id=self.eos_id)


class EncDecGenerateDispatcher(_BucketedGenerate):
    """Bucketed, cache-donating front-end over an enc-dec greedy loop
    (the GEN-FUSER hot path — every served micro-batch ends here).

    Only batch and max_new bucket; the encoder length keys the bucket
    verbatim because this encoder embeds pads like real tokens (no pad
    masking), so padding the encoder axis would perturb real rows.  The
    engine always presents a fixed ``max_fusion_len`` encoder shape, so
    the length axis is already static in practice."""

    def __init__(self, model: EncDecLM, params: dict,
                 pad_id: int = TOKENIZER.pad_id, eos_id: int = TOKENIZER.eos_id,
                 bos_id: int = TOKENIZER.bos_id,
                 ladder: Optional[BucketLadder] = None,
                 donate: Optional[bool] = None):
        super().__init__(params, pad_id, eos_id, ladder, donate)
        self.model = model
        self.bos_id = bos_id

    def _token_bucket(self, s: int) -> int:
        return s  # encoder length is part of the key — never padded

    def _build(self, bb: int, sb: int, nb: int) -> _Entry:
        model, pad_id, eos_id, bos_id = self.model, self.pad_id, self.eos_id, self.bos_id

        def run(params, enc_tokens, cache):
            return encdec_generate_with_cache(
                model, params, enc_tokens, cache, nb, pad_id, eos_id, bos_id
            )

        fn = jax.jit(run, donate_argnums=(2,) if self.donate else ())
        return _Entry(fn=fn, cache=self._make_cache(bb, sb, nb))

    def _make_cache(self, bb: int, sb: int, nb: int) -> dict:
        return self.model.init_cache(bb, nb + 2, enc_seq=sb)

    def _direct(self, tokens: np.ndarray, max_new: int) -> np.ndarray:
        from repro.serve.generate import greedy_generate_encdec

        return greedy_generate_encdec(self.model, self.params, tokens,
                                      max_new=max_new, pad_id=self.pad_id,
                                      eos_id=self.eos_id, bos_id=self.bos_id)
