"""Typed request/response surface for the MODI serving stack.

An :class:`EnsembleRequest` is one user query plus optional per-request
knobs (budget override, policy name, generation length).  The engine
answers with an :class:`EnsembleResponse` carrying the fused text, the
per-member texts and selection mask, realized cost, predicted quality,
and wall-clock timing — everything Table-1 style evaluation or an online
caller needs, without reaching into engine internals.

Requests are what the :class:`repro.serve.scheduler.Scheduler` coalesces
into admission micro-batches; offline evaluation wraps its ``Record``
list into requests and goes through the exact same path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.data.mixinstruct import DOMAIN_NAMES, Record


@dataclasses.dataclass(frozen=True)
class EnsembleRequest:
    """One query for the ensemble.

    ``budget`` overrides the engine's ε-fraction for this request only;
    ``policy`` (a :func:`repro.core.make_policy` name, with optional
    ``policy_kwargs``) overrides the engine's default policy.  ``record``
    carries ground truth for offline evaluation and the behavioural
    simulator; online traffic leaves it ``None``.

    ``priority`` and ``deadline_ticks`` are scheduling hints consumed by
    the continuous-batching :class:`repro.serve.scheduler.Scheduler`:
    higher priority breaks ordering ties, and ``deadline_ticks`` is the
    number of scheduler ticks after arrival by which the request should
    be dispatched (``None`` = best-effort).  Neither affects *what* the
    engine answers — only *when* the request is batched — so responses
    stay byte-identical across scheduling decisions.
    """

    query: str
    budget: Optional[float] = None  # ε as fraction of full-ensemble cost
    policy: Optional[str] = None  # registry name, e.g. "modi", "random"
    policy_kwargs: Optional[Dict[str, Any]] = None
    max_new_tokens: Optional[int] = None
    record: Optional[Record] = None
    priority: int = 0  # larger = more urgent (tie-break within a deadline)
    deadline_ticks: Optional[int] = None  # dispatch-by, relative to arrival

    def resolve_record(self) -> Record:
        """The Record to cost/simulate against (synthesized for online queries)."""
        if self.record is not None:
            return self.record
        return Record(query=self.query, reference="", domain=DOMAIN_NAMES[0], domain_id=0)


@dataclasses.dataclass
class EnsembleResponse:
    """The engine's answer to one :class:`EnsembleRequest`.

    ``degraded``/``missing_members`` mark a *partial-ensemble* answer:
    some pool members were unavailable (failed, or stranded on dead
    hosts) and the knapsack was re-solved over the survivors only —
    best-effort quality inside the same ε budget, rather than no answer.
    ``survivor_cost`` is the full-ensemble cost of just the servable
    members (equal to ``realized_cost / cost_fraction`` when nothing is
    missing) — the base the scheduler settles degraded batches against
    in its rolling admission window."""

    text: str  # GEN-FUSER output
    member_texts: List[Optional[str]]  # [N], None where unselected
    mask: np.ndarray  # [N] bool selection
    realized_cost: float  # FLOPs actually spent on members
    cost_fraction: float  # realized / full-ensemble cost
    predicted_quality: np.ndarray  # [N] predictor scores r_hat
    policy_name: str  # policy that produced the mask
    timing: Dict[str, float]  # stage -> seconds (predict/select/generate/fuse/total)
    degraded: bool = False  # True when members were masked/excluded
    missing_members: Tuple[int, ...] = ()  # the unavailable members
    survivor_cost: float = 0.0  # full cost over servable members only


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One increment of a streamed response (see ``ResponseFuture.stream``).

    ``tokens`` is every fused token emitted so far — cumulative, so a
    consumer can always rebuild its display from the latest event alone.
    ``text`` is the *stable* decoded prefix: the byte stream cut at the
    last complete UTF-8 sequence, so it is guaranteed to be a string
    prefix of the final fused text (a mid-character cut would otherwise
    decode to a replacement char the final text doesn't contain).  The
    closing event has ``final=True`` and carries the settled
    :class:`EnsembleResponse`; its ``text`` is exactly
    ``response.text``."""

    seq: int  # the request's arrival sequence number (trace id)
    tokens: Tuple[int, ...]  # fused tokens emitted so far (cumulative)
    text: str  # stable decoded prefix of the final text
    final: bool = False
    response: Optional[EnsembleResponse] = None  # set on the final event


def requests_from_records(records: List[Record], **overrides) -> List[EnsembleRequest]:
    """Wrap evaluation Records as requests (shared kwargs apply to all)."""
    return [EnsembleRequest(query=r.query, record=r, **overrides) for r in records]
