"""Continuous-batching admission frontend over an :class:`EnsembleServer`.

Online traffic arrives one :class:`EnsembleRequest` at a time; ``submit()``
enqueues the request and returns a :class:`ResponseFuture` immediately.
Beyond the micro-batch coalescing of the original FIFO scheduler, this
frontend is deadline- and budget-aware:

* **EDF batch formation** — pending requests order by
  ``(absolute deadline, -priority, arrival)``; batches are formed from
  requests sharing a *policy group* (the engine's ``_policy_key``), so
  every dispatched micro-batch runs one vectorized ``select``.  Batch
  sizes snap to the :class:`~repro.serve.dispatch.BucketLadder`'s rungs —
  dispatching exactly a rung's worth means the fast path pads by zero
  rows and hits a bucket that is already compiled.
* **Dispatch triggers** — a policy group reaching ``max_batch_size``
  dispatches inline from ``submit``; ``tick()`` (the caller's logical
  clock) dispatches any request that has aged past ``max_wait_ticks`` or
  whose deadline is due; ``flush()`` drains everything;
  ``ResponseFuture.result()`` dispatches *only the batches up to and
  including the one containing that future* — it never force-flushes
  other submitters' young requests.
* **Admission control** — the paper's per-query ε-constraint lifted to a
  rolling per-window fleet budget: realized cost (from
  ``EnsembleResponse.realized_cost``) over the last ``window_ticks`` is
  compared to the full-ensemble cost of the same window; past the soft
  threshold new requests are *downgraded* to a tighter per-request
  budget, past the hard threshold they are *shed* (their future raises
  :class:`RequestShed` — resolved, never hung).
* **Hedged retry** — when a :class:`~repro.serve.backends.MemberFailure`
  escapes the engine mid-batch, the batch is re-served with the failed
  member excluded (``serve_requests(..., exclude_members=...)``) instead
  of failing every sibling future.  Generation is deterministic and
  side-effect-free per call, so the retry is exact, and requests that
  never selected the failed member get byte-identical responses.

Because the engine's request path is deterministic per request (see
``SimBackend``) and batch-position-invariant, a stream served through
this scheduler — under any batching, deadlines, or hedging — produces
byte-identical fused responses to one offline ``EnsembleServer.serve``
call over the same records (``tests/test_traffic_scenarios.py``).

``events`` records every arrival / dispatch / completion / shed / hedge /
deadline-miss as a flat dict — the replayable trace the traffic
simulator (:mod:`repro.serve.traffic`) builds its reports from.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

from repro.serve.api import EnsembleRequest, EnsembleResponse
from repro.serve.backends import MemberFailure
from repro.serve.dispatch import BucketLadder
from repro.serve.engine import EnsembleServer

_NO_DEADLINE = float("inf")


class RequestShed(RuntimeError):
    """Raised by ``ResponseFuture.result()`` when admission control shed
    the request (fleet-level cost budget exceeded)."""


def _digest(text: str) -> str:
    return hashlib.blake2b(text.encode("utf-8", errors="replace"),
                           digest_size=8).hexdigest()


class ResponseFuture:
    """Handle for a submitted request; resolves when its batch is served."""

    def __init__(self, scheduler: "Scheduler", seq: int):
        self._scheduler = scheduler
        self.seq = seq  # arrival sequence number (the trace's request id)
        self._response: Optional[EnsembleResponse] = None
        self._error: Optional[BaseException] = None
        self._done = False
        self.deadline_missed = False  # dispatched after its deadline tick

    def done(self) -> bool:
        return self._done

    def shed(self) -> bool:
        return isinstance(self._error, RequestShed)

    def result(self) -> EnsembleResponse:
        """The response, dispatching this future's own batch if pending.

        Only batches up to and including the one containing this request
        are dispatched — other policy groups and younger same-group
        requests stay queued for their own triggers.  Raises the engine's
        exception if the batch failed, or :class:`RequestShed` if
        admission control dropped the request."""
        if not self._done:
            self._scheduler._dispatch_for(self)
        if self._error is not None:
            raise self._error
        assert self._response is not None
        return self._response

    def _set(self, response: EnsembleResponse) -> None:
        self._response = response
        self._done = True

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._done = True


@dataclasses.dataclass(frozen=True)
class AdmissionControl:
    """Rolling fleet-level ε: per-window realized/full cost thresholds.

    Over the trailing ``window_ticks`` scheduler ticks, the realized
    member cost of every served request is summed against the
    full-ensemble (LLM-BLENDER) cost of the same requests — the same
    fraction the per-query ε constrains, lifted to the fleet.  When the
    window fraction reaches ``downgrade_fraction``, newly submitted
    requests have their per-request budget tightened to
    ``downgrade_budget``; at ``shed_fraction`` they are shed outright.
    ``None`` disables a threshold."""

    window_ticks: int = 8
    downgrade_fraction: Optional[float] = None  # soft: tighten request budgets
    downgrade_budget: float = 0.1  # ε applied to downgraded requests
    shed_fraction: Optional[float] = None  # hard: reject new requests


@dataclasses.dataclass
class _Pending:
    request: EnsembleRequest
    future: ResponseFuture
    key: Tuple  # engine policy-group key
    seq: int
    arrive_tick: int
    deadline_tick: Optional[int]  # absolute (arrival + deadline_ticks)
    priority: int
    age_ticks: int = 0

    def edf_key(self) -> Tuple[float, int, int]:
        d = _NO_DEADLINE if self.deadline_tick is None else self.deadline_tick
        return (d, -self.priority, self.seq)


class Scheduler:
    """Deadline-aware continuous-batching front-end over an EnsembleServer."""

    def __init__(self, server: EnsembleServer, max_batch_size: int = 8,
                 max_wait_ticks: int = 4,
                 admission: Optional[AdmissionControl] = None,
                 ladder: Optional[BucketLadder] = None,
                 hedge: bool = True, record_events: bool = True):
        if max_batch_size < 1:
            raise ValueError("max_batch_size must be >= 1")
        self.server = server
        self.max_batch_size = max_batch_size
        self.max_wait_ticks = max_wait_ticks
        self.admission = admission
        self.ladder = ladder or getattr(server, "bucket_ladder", None) or BucketLadder()
        self.hedge = hedge
        self.record_events = record_events
        self.now = 0
        self._seq = 0
        self.last_submitted: Optional[ResponseFuture] = None
        self._queue: List[_Pending] = []
        # (tick, realized_flops, full_ensemble_flops) per served request —
        # the admission window's ledger
        self._ledger: List[Tuple[int, float, float]] = []
        self.events: List[dict] = []
        self.stats = {
            "submitted": 0, "dispatched_batches": 0, "dispatched_requests": 0,
            "shed": 0, "downgraded": 0, "deadline_misses": 0,
            "hedges": 0, "hedged_requests": 0, "padded_rows": 0,
        }

    # ------------------------------------------------------------------
    def _event(self, event: str, **fields) -> None:
        if self.record_events:
            self.events.append({"tick": self.now, "event": event, **fields})

    # -- admission window ----------------------------------------------
    def _window_ticks(self) -> int:
        return self.admission.window_ticks if self.admission else self.max_wait_ticks

    def window_cost_fraction(self) -> float:
        """Realized/full-ensemble cost over the trailing admission window."""
        floor = self.now - self._window_ticks()
        realized = full = 0.0
        for tick, r, f in self._ledger:
            if tick > floor:
                realized += r
                full += f
        return realized / full if full > 0 else 0.0

    def _admit(self, request: EnsembleRequest,
               future: ResponseFuture) -> Optional[EnsembleRequest]:
        """Admission decision: the request (possibly downgraded), or None
        if it was shed (the future is then already resolved)."""
        ac = self.admission
        if ac is None:
            return request
        frac = self.window_cost_fraction()
        if ac.shed_fraction is not None and frac >= ac.shed_fraction:
            self.stats["shed"] += 1
            self._event("shed", req=future.seq, window_fraction=frac)
            future._fail(RequestShed(
                f"admission window at {frac:.2f} of full-ensemble cost "
                f"(>= shed threshold {ac.shed_fraction:.2f})"
            ))
            return None
        if (ac.downgrade_fraction is not None and frac >= ac.downgrade_fraction
                and (request.budget is None or request.budget > ac.downgrade_budget)):
            self.stats["downgraded"] += 1
            self._event("downgrade", req=future.seq, window_fraction=frac,
                        budget=ac.downgrade_budget)
            return dataclasses.replace(request, budget=ac.downgrade_budget)
        return request

    # ------------------------------------------------------------------
    def submit(self, request: EnsembleRequest) -> ResponseFuture:
        """Enqueue one request; dispatches inline once a policy group fills.

        The request's policy override is fully resolved here (name, kwargs,
        budget), so a malformed request is rejected before it can poison a
        micro-batch shared with other submitters."""
        self.last_submitted: Optional[ResponseFuture] = None
        key = self.server._policy_key(request)
        hash(key)  # unhashable policy_kwargs values would break grouping
        self.server._build_policy(key)  # raises on unknown name / bad kwargs
        future = ResponseFuture(self, self._seq)
        # recoverable by the caller even if an inline dispatch below raises
        # (the batch's futures are resolved with the cause, but submit then
        # propagates before returning the handle)
        self.last_submitted = future
        self._seq += 1
        self.stats["submitted"] += 1
        admitted = self._admit(request, future)
        if admitted is None:
            return future  # shed: resolved with RequestShed, never queued
        if admitted is not request:
            key = self.server._policy_key(admitted)  # downgrade moved the group
        deadline = (None if admitted.deadline_ticks is None
                    else self.now + admitted.deadline_ticks)
        self._queue.append(_Pending(
            request=admitted, future=future, key=key, seq=future.seq,
            arrive_tick=self.now, deadline_tick=deadline,
            priority=admitted.priority,
        ))
        self._event("arrive", req=future.seq, key=repr(key),
                    deadline=deadline, priority=admitted.priority)
        while True:
            group = self._largest_group()
            if len(group) < self.max_batch_size:
                break
            self._dispatch_group(group, forced=self.max_batch_size)
        return future

    def tick(self) -> int:
        """Advance the logical clock; dispatch every request that has aged
        past ``max_wait_ticks`` or whose deadline tick is due.  Returns the
        number of requests dispatched this tick."""
        self.now += 1
        for p in self._queue:
            p.age_ticks += 1
        served = 0
        while True:
            urgent = [p for p in self._queue if self._urgent(p)]
            if not urgent:
                break
            head = min(urgent, key=_Pending.edf_key)
            group = self._group(head.key)
            forced = sum(self._urgent(p) for p in group[:self.max_batch_size])
            served += self._dispatch_group(group, forced=max(forced, 1))
        return served

    def flush(self) -> int:
        """Dispatch everything queued, regardless of age, deadline, or rung."""
        served = 0
        while self._queue:
            head = min(self._queue, key=_Pending.edf_key)
            group = self._group(head.key)
            served += self._dispatch_group(
                group, forced=min(len(group), self.max_batch_size))
        return served

    @property
    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------------
    def _urgent(self, p: _Pending) -> bool:
        if p.age_ticks >= self.max_wait_ticks:
            return True
        return p.deadline_tick is not None and p.deadline_tick <= self.now

    def _group(self, key: Tuple) -> List[_Pending]:
        """The pending requests of one policy group, in EDF order."""
        return sorted((p for p in self._queue if p.key == key),
                      key=_Pending.edf_key)

    def _largest_group(self) -> List[_Pending]:
        counts: Dict[Tuple, int] = {}
        for p in self._queue:
            counts[p.key] = counts.get(p.key, 0) + 1
        if not counts:
            return []
        key = max(counts, key=lambda k: counts[k])
        return self._group(key)

    def _dispatch_for(self, future: ResponseFuture) -> None:
        """Dispatch batches from this future's policy group — in EDF order,
        so same-group requests ahead of it ride along — until the batch
        containing it has been served.  Other groups are left queued."""
        while not future.done():
            entry = next((p for p in self._queue if p.future is future), None)
            if entry is None:  # resolved concurrently or never queued
                break
            group = self._group(entry.key)
            ahead = group.index(entry) + 1  # everything up to and incl. it
            self._dispatch_group(group, forced=min(ahead, self.max_batch_size))

    # ------------------------------------------------------------------
    def _take_count(self, available: int, forced: int) -> int:
        """How many of a group's EDF-ordered candidates to dispatch.

        Snap down to the largest bucket-ladder rung <= available so the
        fast path pads by zero rows — unless that would strand a request
        that must go now (``forced``), in which case take all forced
        requests and pad up to the enclosing (still pre-compiled) rung."""
        available = min(available, self.max_batch_size)
        forced = min(forced, available)
        if available == self.ladder.batch_bucket(available):
            return available  # already exactly on a rung
        return max(self.ladder.floor_batch_rung(available), forced, 1)

    def _dispatch_group(self, group: List[_Pending], forced: int) -> int:
        """Serve the front of one policy group; returns requests served."""
        if not group:
            return 0
        take = self._take_count(len(group), forced)
        batch = group[:take]
        members = set(id(p) for p in batch)
        self._queue = [p for p in self._queue if id(p) not in members]
        exclude: frozenset = frozenset()
        reqs = [p.request for p in batch]
        while True:
            try:
                if exclude:
                    responses = self.server.serve_requests(
                        reqs, exclude_members=exclude)
                else:
                    responses = self.server.serve_requests(reqs)
                break
            except MemberFailure as mf:
                pool_n = self.server.backend.num_members()
                if not self.hedge or len(exclude) + 1 >= pool_n:
                    for p in batch:
                        p.future._fail(mf)
                    raise
                exclude = exclude | {mf.member_idx}
                self.stats["hedges"] += 1
                self.stats["hedged_requests"] += len(batch)
                self._event("hedge", member=mf.member_idx,
                            reqs=[p.seq for p in batch],
                            exclude=sorted(exclude))
            except Exception as exc:
                # the batch is already popped; resolve every sibling future
                # with the cause instead of leaving them pending forever
                for p in batch:
                    p.future._fail(exc)
                raise
        self._event("dispatch", reqs=[p.seq for p in batch], size=len(batch),
                    bucket=self.ladder.batch_bucket(len(batch)),
                    exclude=sorted(exclude))
        self.stats["padded_rows"] += (
            self.ladder.batch_bucket(len(batch)) - len(batch))
        for p, response in zip(batch, responses):
            p.future._set(response)
            missed = (p.deadline_tick is not None and self.now > p.deadline_tick)
            if missed:
                p.future.deadline_missed = True
                self.stats["deadline_misses"] += 1
                self._event("miss", req=p.seq, deadline=p.deadline_tick)
            # full-ensemble cost backed out of the realized fraction keeps
            # the ledger exact for any policy without a second cost pass
            full = (response.realized_cost / response.cost_fraction
                    if response.cost_fraction > 0 else 0.0)
            self._ledger.append((self.now, response.realized_cost, full))
            self._event("complete", req=p.seq,
                        latency_ticks=self.now - p.arrive_tick,
                        missed=missed, text_digest=_digest(response.text))
        self.stats["dispatched_batches"] += 1
        self.stats["dispatched_requests"] += len(batch)
        # entries older than the window can never matter again — prune so
        # the ledger stays O(window), not O(session)
        floor = self.now - self._window_ticks()
        self._ledger = [e for e in self._ledger if e[0] > floor]
        return len(batch)
