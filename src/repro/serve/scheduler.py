"""Admission scheduler: coalesces individual requests into micro-batches.

Online traffic arrives one :class:`EnsembleRequest` at a time;
``submit()`` enqueues the request and returns a :class:`ResponseFuture`
immediately.  A micro-batch is dispatched to the engine when

* the queue reaches ``max_batch_size`` (dispatched inline from
  ``submit``), or
* a queued request has waited ``max_wait_ticks`` logical ticks
  (``tick()`` is the caller's clock — one call per poll/step), or
* the caller forces it (``flush()``, or ``ResponseFuture.result()`` on a
  still-pending request).

Because the engine's request path is deterministic per request (see
``SimBackend``), a stream served one-at-a-time through the scheduler
produces byte-identical fused responses to one big offline
``EnsembleServer.serve`` call over the same records — the property
``tests/test_serve_api.py`` pins down.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.serve.api import EnsembleRequest, EnsembleResponse
from repro.serve.engine import EnsembleServer


class ResponseFuture:
    """Handle for a submitted request; resolves when its batch is served."""

    def __init__(self, scheduler: "Scheduler"):
        self._scheduler = scheduler
        self._response: Optional[EnsembleResponse] = None
        self._error: Optional[BaseException] = None
        self._done = False

    def done(self) -> bool:
        return self._done

    def result(self) -> EnsembleResponse:
        """The response, flushing the scheduler if still queued.

        Raises the engine's exception if this request's micro-batch failed."""
        if not self._done:
            self._scheduler.flush()
        if self._error is not None:
            raise self._error
        assert self._response is not None
        return self._response

    def _set(self, response: EnsembleResponse) -> None:
        self._response = response
        self._done = True

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._done = True


@dataclasses.dataclass
class _Pending:
    request: EnsembleRequest
    future: ResponseFuture
    age_ticks: int = 0


class Scheduler:
    """Micro-batching front-end over an :class:`EnsembleServer`."""

    def __init__(self, server: EnsembleServer, max_batch_size: int = 8,
                 max_wait_ticks: int = 4):
        if max_batch_size < 1:
            raise ValueError("max_batch_size must be >= 1")
        self.server = server
        self.max_batch_size = max_batch_size
        self.max_wait_ticks = max_wait_ticks
        self._queue: List[_Pending] = []
        self.stats = {"submitted": 0, "dispatched_batches": 0, "dispatched_requests": 0}

    # ------------------------------------------------------------------
    def submit(self, request: EnsembleRequest) -> ResponseFuture:
        """Enqueue one request; dispatches inline once a full batch forms.

        The request's policy override is fully resolved here (name, kwargs,
        budget), so a malformed request is rejected before it can poison a
        micro-batch shared with other submitters."""
        key = self.server._policy_key(request)
        hash(key)  # unhashable policy_kwargs values would break grouping
        self.server._build_policy(key)  # raises on unknown name / bad kwargs
        future = ResponseFuture(self)
        self._queue.append(_Pending(request, future))
        self.stats["submitted"] += 1
        while len(self._queue) >= self.max_batch_size:
            self._dispatch(self.max_batch_size)
        return future

    def tick(self) -> int:
        """Advance the logical clock; dispatch batches that waited too long.

        Returns the number of requests dispatched this tick."""
        for p in self._queue:
            p.age_ticks += 1
        served = 0
        while self._queue and self._queue[0].age_ticks >= self.max_wait_ticks:
            served += self._dispatch(self.max_batch_size)
        return served

    def flush(self) -> int:
        """Dispatch everything queued, regardless of age or batch size."""
        served = 0
        while self._queue:
            served += self._dispatch(self.max_batch_size)
        return served

    @property
    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------------
    def _dispatch(self, limit: int) -> int:
        batch, self._queue = self._queue[:limit], self._queue[limit:]
        if not batch:
            return 0
        try:
            responses = self.server.serve_requests([p.request for p in batch])
        except Exception as exc:
            # the batch is already popped; resolve every sibling future with
            # the cause instead of leaving them pending forever
            for p in batch:
                p.future._fail(exc)
            raise
        for p, response in zip(batch, responses):
            p.future._set(response)
        self.stats["dispatched_batches"] += 1
        self.stats["dispatched_requests"] += len(batch)
        return len(batch)
