"""Continuous-batching admission frontend over an :class:`EnsembleServer`.

Online traffic arrives one :class:`EnsembleRequest` at a time; ``submit()``
enqueues the request and returns a :class:`ResponseFuture` immediately.
Beyond the micro-batch coalescing of the original FIFO scheduler, this
frontend is deadline- and budget-aware:

* **EDF batch formation** — pending requests order by
  ``(absolute deadline, -priority, arrival)``; batches are formed from
  requests sharing a *policy group* (the engine's ``_policy_key``), so
  every dispatched micro-batch runs one vectorized ``select``.  Batch
  sizes snap to the :class:`~repro.serve.dispatch.BucketLadder`'s rungs —
  dispatching exactly a rung's worth means the fast path pads by zero
  rows and hits a bucket that is already compiled.
* **Dispatch triggers** — a policy group reaching ``max_batch_size``
  dispatches inline from ``submit``; ``tick()`` (the caller's logical
  clock) dispatches any request that has aged past ``max_wait_ticks`` or
  whose deadline is due; ``flush()`` drains everything;
  ``ResponseFuture.result()`` dispatches *only the batches up to and
  including the one containing that future* — it never force-flushes
  other submitters' young requests.
* **Async dispatch** — ``sync=False`` moves batch *service* (the engine
  call and its hedged retries) onto a
  :class:`~repro.serve.cluster.DispatchWorker` thread with a bounded
  inbox: batch formation stays on the caller's thread, so ``submit``
  returns as soon as the batch is enqueued and never blocks on a batch.
  The worker executes batches FIFO and every event carries the logical
  tick its batch was *dispatched* at, so the event trace is byte-
  identical to the ``sync=True`` path (pinned per preset scenario by
  ``tests/test_serve_cluster.py``).  Errors surface at ``result()``
  instead of propagating from ``submit``/``tick``.
* **Admission control** — the paper's per-query ε-constraint lifted to a
  rolling per-window fleet budget: realized cost (from
  ``EnsembleResponse.realized_cost``) over the last ``window_ticks`` is
  compared to the full-ensemble cost of the same window; past the soft
  threshold new requests are *downgraded* to a tighter per-request
  budget, past the hard threshold they are *shed* (their future raises
  :class:`RequestShed` — resolved, never hung).  With
  ``deadline_aware=True`` a request whose predicted queue delay (EWMA of
  recent inter-dispatch gaps × batches ahead of it) already exceeds its
  ``deadline_ticks`` is shed at admission — reason ``deadline`` — instead
  of being served late.  In async mode a full worker inbox sheds with
  reason ``backpressure`` — checked before anything waits, at admission
  and again at dispatch time — while the threshold decisions read
  realized-cost feedback and so synchronize with in-flight batches
  first (the documented feedback sync point — an admission-free
  scheduler never blocks, except on the bounded inbox itself).
* **Hedged retry** — when a :class:`~repro.serve.backends.MemberFailure`
  escapes the engine mid-batch, the batch is re-served with the failed
  member excluded (``serve_requests(..., exclude_members=...)``) instead
  of failing every sibling future.  A whole-host death
  (:class:`~repro.serve.backends.HostFailure`, raised by the cluster
  router when a host takes its last replicas down) escalates the same
  way, but re-serves with the dead members *masked out of the knapsack*
  (``masked_members=``): budget-aware policies re-solve over the
  survivors' costs.  Generation is deterministic and side-effect-free
  per call, so retries are exact, and requests that never selected the
  failed members get byte-identical responses.

Because the engine's request path is deterministic per request (see
``SimBackend``) and batch-position-invariant, a stream served through
this scheduler — under any batching, deadlines, hedging, or dispatch
mode — produces byte-identical fused responses to one offline
``EnsembleServer.serve`` call over the same records
(``tests/test_traffic_scenarios.py``, ``tests/test_serve_cluster.py``).

``events`` records every arrival / dispatch / completion / shed / hedge /
deadline-miss as a flat dict — the replayable trace the traffic
simulator (:mod:`repro.serve.traffic`) builds its reports from.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Dict, Iterator, List, Optional, Tuple

from repro.data.tokenizer import TOKENIZER
from repro.serve.api import EnsembleRequest, EnsembleResponse, StreamEvent
from repro.serve.backends import HostFailure, MemberFailure
from repro.serve.cluster.worker import DispatchWorker, InboxFull
from repro.serve.dispatch import BucketLadder
from repro.serve.engine import EnsembleServer

_NO_DEADLINE = float("inf")


class RequestShed(RuntimeError):
    """Raised by ``ResponseFuture.result()`` when admission control shed
    the request (fleet budget, hopeless deadline, or backpressure)."""


def _digest(text: str) -> str:
    return hashlib.blake2b(text.encode("utf-8", errors="replace"),
                           digest_size=8).hexdigest()


class ResponseFuture:
    """Handle for a submitted request; resolves when its batch is served."""

    def __init__(self, scheduler: "Scheduler", seq: int):
        self._scheduler = scheduler
        self.seq = seq  # arrival sequence number (the trace's request id)
        self._response: Optional[EnsembleResponse] = None
        self._error: Optional[BaseException] = None
        self._done = False
        self._resolved = threading.Event()
        # makes resolve-vs-timeout atomic: _set/_fail hold it, so an
        # expiring wait can re-check before declaring a timeout
        self._resolve_lock = threading.Lock()
        self._stream_cv = threading.Condition()
        self._stream_events: List[StreamEvent] = []
        self.deadline_missed = False  # dispatched after its deadline tick
        self.ttft_s: Optional[float] = None  # wall s to first streamed token

    def done(self) -> bool:
        return self._done

    def shed(self) -> bool:
        return isinstance(self._error, RequestShed)

    def result(self, timeout: Optional[float] = None) -> EnsembleResponse:
        """The response, dispatching this future's own batch if pending.

        Only batches up to and including the one containing this request
        are dispatched — other policy groups and younger same-group
        requests stay queued for their own triggers.  In async mode the
        call blocks until the worker has served the batch (``timeout``
        in seconds bounds the wait).  Raises the engine's exception if
        the batch failed, or :class:`RequestShed` if admission control
        dropped the request."""
        if not self._done:
            self._scheduler._dispatch_for(self)
            if not self._resolved.wait(timeout):
                # the wait expired — but the batch may have resolved between
                # the expiring wait and this line.  Re-check under the lock
                # _set/_fail hold, so a served request can never surface as
                # a TimeoutError (or spuriously bump result_timeouts / the
                # "timeout" trace event).
                with self._resolve_lock:
                    if not self._done:
                        # the batch stays in flight on the worker — record
                        # the abandoned wait in the trace (a silent
                        # TimeoutError used to leave no evidence) and keep
                        # the future resolvable: a later result() call
                        # returns normally once the batch lands
                        self._scheduler._note_result_timeout(self, timeout)
                        raise TimeoutError(
                            f"request {self.seq} not served within {timeout}s")
        if self._error is not None:
            raise self._error
        assert self._response is not None
        return self._response

    def stream(self, timeout: Optional[float] = None) -> Iterator[StreamEvent]:
        """Iterate this request's :class:`StreamEvent` increments as its
        fusion decodes, ending with a ``final=True`` event that carries the
        settled :class:`EnsembleResponse`.

        Like :meth:`result`, iterating dispatches this future's own batch
        if it is still queued.  Under a streaming scheduler events arrive
        one per decode step of this request's row; under a non-streaming
        scheduler (or the engine's coarse fallback) the iterator degrades
        to a single pass over whatever was buffered plus the final event.
        ``timeout`` bounds each wait for the *next* event; a failed or
        shed request raises from the iterator exactly as ``result()``
        would."""
        self._scheduler._dispatch_for(self)
        i = 0
        while True:
            with self._stream_cv:
                while len(self._stream_events) <= i and not self._done:
                    if not self._stream_cv.wait(timeout):
                        raise TimeoutError(
                            f"request {self.seq}: no stream progress "
                            f"within {timeout}s")
                pending = list(self._stream_events[i:])
                i += len(pending)
                finished = self._done and len(self._stream_events) == i
            yield from pending
            if finished:
                break
        response = self.result(timeout)  # raises the batch error / shed
        with self._stream_cv:
            last = self._stream_events[-1].tokens if self._stream_events else ()
        yield StreamEvent(seq=self.seq, tokens=last, text=response.text,
                          final=True, response=response)

    def _push_stream(self, tokens: List[int]) -> None:
        ev = StreamEvent(
            seq=self.seq, tokens=tuple(tokens),
            text=TOKENIZER.decode_capped(tokens, len(tokens)))
        with self._stream_cv:
            self._stream_events.append(ev)
            self._stream_cv.notify_all()

    def _set(self, response: EnsembleResponse) -> None:
        with self._resolve_lock:
            self._response = response
            self._done = True
            self._resolved.set()
        with self._stream_cv:
            self._stream_cv.notify_all()

    def _fail(self, error: BaseException) -> None:
        with self._resolve_lock:
            self._error = error
            self._done = True
            self._resolved.set()
        with self._stream_cv:
            self._stream_cv.notify_all()


@dataclasses.dataclass(frozen=True)
class AdmissionControl:
    """Rolling fleet-level ε plus deadline-feasibility admission.

    Over the trailing ``window_ticks`` scheduler ticks, the realized
    member cost of every served request is summed against the
    full-ensemble (LLM-BLENDER) cost of the same requests — the same
    fraction the per-query ε constrains, lifted to the fleet.  When the
    window fraction reaches ``downgrade_fraction``, newly submitted
    requests have their per-request budget tightened to
    ``downgrade_budget``; at ``shed_fraction`` they are shed outright.
    ``None`` disables a threshold.

    ``deadline_aware=True`` additionally sheds requests that cannot make
    their deadline: the scheduler keeps an EWMA (smoothing
    ``service_alpha``) of recent inter-dispatch gaps in ticks — how many
    ticks one batch of service currently costs — and predicts a new
    request's queue delay as that EWMA times the number of batches ahead
    of it.  A request whose ``deadline_ticks`` is below the prediction is
    shed at admission (event reason ``deadline``) rather than served
    past-deadline.  Requests without a deadline are never deadline-shed."""

    window_ticks: int = 8
    downgrade_fraction: Optional[float] = None  # soft: tighten request budgets
    downgrade_budget: float = 0.1  # ε applied to downgraded requests
    shed_fraction: Optional[float] = None  # hard: reject new requests
    deadline_aware: bool = False  # shed requests that cannot make their deadline
    service_alpha: float = 0.5  # EWMA smoothing for inter-dispatch gap ticks

    def needs_feedback(self) -> bool:
        """Whether admission decisions read served-batch feedback (and so
        must synchronize with in-flight batches in async mode)."""
        return (self.downgrade_fraction is not None
                or self.shed_fraction is not None
                or self.deadline_aware)


@dataclasses.dataclass
class _Pending:
    request: EnsembleRequest
    future: ResponseFuture
    key: Tuple  # engine policy-group key
    seq: int
    arrive_tick: int
    deadline_tick: Optional[int]  # absolute (arrival + deadline_ticks)
    priority: int
    age_ticks: int = 0

    def edf_key(self) -> Tuple[float, int, int]:
        d = _NO_DEADLINE if self.deadline_tick is None else self.deadline_tick
        return (d, -self.priority, self.seq)


@dataclasses.dataclass
class _BatchJob:
    """One formed batch, ready for service (inline or on the worker).

    ``dispatch_tick`` freezes the logical clock at formation time: every
    event, deadline-miss decision, and ledger entry the service produces
    is stamped with it, so the trace is identical whether the engine call
    runs inline or finishes on the worker thread several ticks later.
    ``events`` is this batch's pre-reserved slot in the scheduler's event
    log — the worker appends into it without racing later arrivals."""

    batch: List[_Pending]
    dispatch_tick: int
    events: List[dict]


class Scheduler:
    """Deadline-aware continuous-batching front-end over an EnsembleServer."""

    def __init__(self, server: EnsembleServer, max_batch_size: int = 8,
                 max_wait_ticks: int = 4,
                 admission: Optional[AdmissionControl] = None,
                 ladder: Optional[BucketLadder] = None,
                 hedge: bool = True, record_events: bool = True,
                 sync: bool = True, inbox_capacity: int = 64,
                 allow_degraded: bool = False, stream: bool = False,
                 stream_capacity: int = 8,
                 prefill_chunk: Optional[int] = None):
        if max_batch_size < 1:
            raise ValueError("max_batch_size must be >= 1")
        self.server = server
        self.max_batch_size = max_batch_size
        self.max_wait_ticks = max_wait_ticks
        self.admission = admission
        self.ladder = ladder or getattr(server, "bucket_ladder", None) or BucketLadder()
        self.hedge = hedge
        # serve partial-ensemble responses (knapsack re-solved over the
        # survivors, tagged degraded=True, settled against the survivors'
        # full cost) even when ``hedge`` is off; a total outage — every
        # member unavailable — still raises
        self.allow_degraded = allow_degraded
        self.record_events = record_events
        self.sync = sync
        # token-level continuous batching: batches fuse through the
        # engine's persistent stream fuser, pushing per-step token events
        # into each row's ResponseFuture (see enable_streaming)
        self.stream = stream
        self.stream_capacity = stream_capacity
        self.prefill_chunk = prefill_chunk
        self.now = 0
        self._seq = 0
        self.last_submitted: Optional[ResponseFuture] = None
        self._queue: List[_Pending] = []
        # (tick, realized_flops, full_ensemble_flops) per served request —
        # the admission window's ledger
        self._ledger: List[Tuple[int, float, float]] = []
        # event log: flat dicts for submit-side events, one nested list per
        # dispatched batch (the batch's slot, reserved in dispatch order and
        # filled by whichever thread serves it) — see the `events` property
        self._events: List = []
        self._lock = threading.Lock()
        self._service_ewma: Optional[float] = None  # inter-dispatch gap ticks
        self._last_dispatch_tick: Optional[int] = None
        self._worker: Optional[DispatchWorker] = None
        if not sync:
            self._worker = DispatchWorker(self._serve_batch,
                                          capacity=inbox_capacity,
                                          on_orphan=self._orphan_batch)
        self.stats = {
            "submitted": 0, "dispatched_batches": 0, "dispatched_requests": 0,
            "shed": 0, "downgraded": 0, "deadline_misses": 0,
            "hedges": 0, "host_hedges": 0, "hedged_requests": 0,
            "padded_rows": 0, "result_timeouts": 0, "degraded_responses": 0,
            "stream_tokens": 0,
        }

    def enable_streaming(self, capacity: Optional[int] = None,
                         prefill_chunk: Optional[int] = None) -> None:
        """Flip this scheduler onto the token-level continuous-batching
        fusion path (``--stream`` / the ``streaming`` traffic preset).
        Final responses — and the whole event trace — are byte-identical
        to the batch-boundary path; only the decode mechanics and the
        incremental :class:`StreamEvent` feed change."""
        self.stream = True
        if capacity is not None:
            self.stream_capacity = capacity
        if prefill_chunk is not None:
            self.prefill_chunk = prefill_chunk

    # ------------------------------------------------------------------
    @property
    def events(self) -> List[dict]:
        """The flat event trace: batch slots flatten in dispatch order, so
        the sequence is deterministic regardless of dispatch mode."""
        out: List[dict] = []
        for e in self._events:
            if isinstance(e, list):
                out.extend(e)
            else:
                out.append(e)
        return out

    def _event(self, event: str, **fields) -> None:
        if self.record_events:
            self._events.append({"tick": self.now, "event": event, **fields})

    def _event_to(self, target: List[dict], tick: int, event: str,
                  **fields) -> None:
        if self.record_events:
            target.append({"tick": tick, "event": event, **fields})

    # -- admission window ----------------------------------------------
    def _window_ticks(self) -> int:
        return self.admission.window_ticks if self.admission else self.max_wait_ticks

    def window_cost_fraction(self) -> float:
        """Realized/full-ensemble cost over the trailing admission window."""
        floor = self.now - self._window_ticks()
        with self._lock:
            ledger = list(self._ledger)
        realized = full = 0.0
        for tick, r, f in ledger:
            if tick > floor:
                realized += r
                full += f
        return realized / full if full > 0 else 0.0

    def predicted_queue_delay(self) -> float:
        """Predicted ticks a request submitted now waits before dispatch:
        the inter-dispatch-gap EWMA times the batches queued ahead of it.
        0 until the first gap is observed (an idle scheduler admits)."""
        with self._lock:
            ewma = self._service_ewma
        if ewma is None:
            return 0.0
        batches_ahead = len(self._queue) // self.max_batch_size + 1
        return ewma * batches_ahead

    def _note_result_timeout(self, future: ResponseFuture,
                             timeout: Optional[float]) -> None:
        """Trace a ``result(timeout=)`` expiring while its batch is still
        in flight.  Not a shed — the batch will land and a later
        ``result()`` resolves — but the abandoned wait must be trace
        evidence, not silence."""
        with self._lock:
            self.stats["result_timeouts"] += 1
        self._event("timeout", req=future.seq, waited_s=timeout)

    def _orphan_batch(self, job: "_BatchJob") -> None:
        """Resolve a batch the dispatch worker accepted but never ran
        (it raced past the closed check): same error a losing
        ``try_submit`` sees, so no accepted future can hang."""
        exc = RuntimeError("worker is closed")
        for p in job.batch:
            p.future._fail(exc)

    def _shed(self, future: ResponseFuture, reason: str, detail: str,
              **fields) -> None:
        self.stats["shed"] += 1
        self._event("shed", req=future.seq, reason=reason, **fields)
        future._fail(RequestShed(detail))

    def _admit(self, request: EnsembleRequest,
               future: ResponseFuture) -> Optional[EnsembleRequest]:
        """Admission decision: the request (possibly downgraded), or None
        if it was shed (the future is then already resolved)."""
        ac = self.admission
        if ac is None:
            return request
        if self._worker is not None and self._worker.full():
            # backpressure first: when the inbox is already full, shedding
            # must not wait on the feedback sync point below (the most
            # loaded moment is exactly when waiting hurts most)
            self._shed(
                future, "backpressure",
                f"dispatch inbox at capacity ({self._worker.capacity})")
            return None
        if self._worker is not None and ac.needs_feedback():
            # feedback sync point: thresholds compare against realized
            # cost and service-gap EWMAs, which in-flight batches are
            # still producing — wait for them so sync and async modes
            # make identical admission decisions
            self._worker.join()
        frac = self.window_cost_fraction()
        if ac.shed_fraction is not None and frac >= ac.shed_fraction:
            self._shed(
                future, "budget",
                f"admission window at {frac:.2f} of full-ensemble cost "
                f"(>= shed threshold {ac.shed_fraction:.2f})",
                window_fraction=frac)
            return None
        if ac.deadline_aware and request.deadline_ticks is not None:
            predicted = self.predicted_queue_delay()
            if predicted > request.deadline_ticks:
                self._shed(
                    future, "deadline",
                    f"predicted queue delay {predicted:.1f} ticks exceeds "
                    f"deadline {request.deadline_ticks}",
                    predicted_delay=predicted,
                    deadline_ticks=request.deadline_ticks)
                return None
        if (ac.downgrade_fraction is not None and frac >= ac.downgrade_fraction
                and (request.budget is None or request.budget > ac.downgrade_budget)):
            self.stats["downgraded"] += 1
            self._event("downgrade", req=future.seq, window_fraction=frac,
                        budget=ac.downgrade_budget)
            return dataclasses.replace(request, budget=ac.downgrade_budget)
        return request

    # ------------------------------------------------------------------
    def submit(self, request: EnsembleRequest) -> ResponseFuture:
        """Enqueue one request; dispatches inline once a policy group fills.

        The request's policy override is fully resolved here (name, kwargs,
        budget), so a malformed request is rejected before it can poison a
        micro-batch shared with other submitters.  In async mode a full
        policy group only *enqueues* its batch — the call never waits for
        the engine."""
        self.last_submitted: Optional[ResponseFuture] = None
        key = self.server._policy_key(request)
        hash(key)  # unhashable policy_kwargs values would break grouping
        self.server._build_policy(key)  # raises on unknown name / bad kwargs
        future = ResponseFuture(self, self._seq)
        # recoverable by the caller even if an inline dispatch below raises
        # (the batch's futures are resolved with the cause, but submit then
        # propagates before returning the handle)
        self.last_submitted = future
        self._seq += 1
        self.stats["submitted"] += 1
        admitted = self._admit(request, future)
        if admitted is None:
            return future  # shed: resolved with RequestShed, never queued
        if admitted is not request:
            key = self.server._policy_key(admitted)  # downgrade moved the group
        deadline = (None if admitted.deadline_ticks is None
                    else self.now + admitted.deadline_ticks)
        self._queue.append(_Pending(
            request=admitted, future=future, key=key, seq=future.seq,
            arrive_tick=self.now, deadline_tick=deadline,
            priority=admitted.priority,
        ))
        self._event("arrive", req=future.seq, key=repr(key),
                    deadline=deadline, priority=admitted.priority)
        while True:
            group = self._largest_group()
            if len(group) < self.max_batch_size:
                break
            self._dispatch_group(group, forced=self.max_batch_size)
        return future

    def tick(self) -> int:
        """Advance the logical clock; dispatch every request that has aged
        past ``max_wait_ticks`` or whose deadline tick is due.  Returns the
        number of requests dispatched this tick.  Cluster maintenance
        (host revival after probation, replica rebalance) runs first, so
        batches formed this tick already route through the healed
        placement."""
        self.now += 1
        self._maintain_cluster()
        for p in self._queue:
            p.age_ticks += 1
        served = 0
        while True:
            urgent = [p for p in self._queue if self._urgent(p)]
            if not urgent:
                break
            head = min(urgent, key=_Pending.edf_key)
            group = self._group(head.key)
            forced = sum(self._urgent(p) for p in group[:self.max_batch_size])
            served += self._dispatch_group(group, forced=max(forced, 1))
        return served

    def _maintain_cluster(self) -> None:
        """Apply due placement maintenance (cluster backends only): host
        revival once a recovery's probation window has elapsed, and
        replica re-placement for members that lost redundancy.  In-flight
        shards are drained first (``join``) so migration never races
        generation.  The pending-check reads only static schedule state —
        deciding from live host health would let an in-flight async batch
        (whose fault is about to flip a host dead) make this tick's
        decision differ from sync mode's — so the drain happens exactly
        on ticks where maintenance *might* apply, and the precise
        decision runs on drained state: maintenance events land in the
        flat trace at identical ticks in both dispatch modes.  Fleets
        with no recovery schedule and no rebalance never pay the
        barrier."""
        backend = self.server.backend
        pending = getattr(backend, "maintenance_pending", None)
        if not callable(pending) or not pending(self.now):
            return
        self.join()  # drain in-flight shards before migrating placement
        for ev in backend.maintain(self.now):
            ev = dict(ev)
            self._event(ev.pop("event"), **ev)

    def flush(self) -> int:
        """Dispatch everything queued, regardless of age, deadline, or rung."""
        served = 0
        while self._queue:
            head = min(self._queue, key=_Pending.edf_key)
            group = self._group(head.key)
            served += self._dispatch_group(
                group, forced=min(len(group), self.max_batch_size))
        return served

    def join(self) -> None:
        """Wait until every dispatched batch has been served.  A no-op in
        sync mode, where dispatch and service are the same step."""
        if self._worker is not None:
            self._worker.join()

    def close(self) -> None:
        """Stop the dispatch worker (async mode).  Queued-but-undispatched
        requests stay queued; in-flight batches finish first."""
        if self._worker is not None:
            self._worker.close()

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def in_flight(self) -> int:
        """Batches dispatched but not yet served (always 0 in sync mode)."""
        return self._worker.depth if self._worker is not None else 0

    # ------------------------------------------------------------------
    def _urgent(self, p: _Pending) -> bool:
        if p.age_ticks >= self.max_wait_ticks:
            return True
        return p.deadline_tick is not None and p.deadline_tick <= self.now

    def _group(self, key: Tuple) -> List[_Pending]:
        """The pending requests of one policy group, in EDF order."""
        return sorted((p for p in self._queue if p.key == key),
                      key=_Pending.edf_key)

    def _largest_group(self) -> List[_Pending]:
        counts: Dict[Tuple, int] = {}
        for p in self._queue:
            counts[p.key] = counts.get(p.key, 0) + 1
        if not counts:
            return []
        key = max(counts, key=lambda k: counts[k])
        return self._group(key)

    def _dispatch_for(self, future: ResponseFuture) -> None:
        """Dispatch batches from this future's policy group — in EDF order,
        so same-group requests ahead of it ride along — until the batch
        containing it has been dispatched.  Other groups are left queued.
        In async mode the batch may still be in flight on return; the
        future's event resolves it (``result()`` waits on it)."""
        while not future.done():
            entry = next((p for p in self._queue if p.future is future), None)
            if entry is None:  # in flight, resolved concurrently, or never queued
                break
            group = self._group(entry.key)
            ahead = group.index(entry) + 1  # everything up to and incl. it
            self._dispatch_group(group, forced=min(ahead, self.max_batch_size))
        if self._worker is None and not future.done():
            # sync mode must resolve before returning; the event-based wait
            # in result() would deadlock on a future nobody will serve
            raise RuntimeError(f"request {future.seq} failed to dispatch")

    # ------------------------------------------------------------------
    def _take_count(self, available: int, forced: int) -> int:
        """How many of a group's EDF-ordered candidates to dispatch.

        Snap down to the largest bucket-ladder rung <= available so the
        fast path pads by zero rows — unless that would strand a request
        that must go now (``forced``), in which case take all forced
        requests and pad up to the enclosing (still pre-compiled) rung.
        Never exceeds the ladder's top rung: a count above it (possible
        when ``max_batch_size`` is configured past the ladder, via either
        the exact-rung early return — ``batch_bucket`` falls back to the
        next power of two beyond the top — or ``forced`` itself) would
        compile a brand-new bucket on every steady-state dispatch.  The
        clamped remainder dispatches as a follow-on batch (see
        ``_dispatch_group``) instead."""
        top = self.ladder.batch[-1]
        available = min(available, self.max_batch_size, top)
        forced = min(forced, available)
        if available == self.ladder.batch_bucket(available):
            return available  # already exactly on a rung
        return max(self.ladder.floor_batch_rung(available), forced, 1)

    def _dispatch_group(self, group: List[_Pending], forced: int) -> int:
        """Pop the front of one policy group into a batch job and hand it
        to service — inline in sync mode, the worker's inbox otherwise.
        Returns requests dispatched."""
        if not group:
            return 0
        take = self._take_count(len(group), forced)
        batch = group[:take]
        members = set(id(p) for p in batch)
        self._queue = [p for p in self._queue if id(p) not in members]
        job = _BatchJob(batch=batch, dispatch_tick=self.now, events=[])
        if self.record_events:
            self._events.append(job.events)  # reserve the trace slot now
        if self._worker is None:
            self._serve_batch(job)
        else:
            try:
                if self.admission is not None:
                    # admission-controlled: never block on a full inbox —
                    # shed the batch with the backpressure reason instead
                    # (closes the admit-time-check / dispatch-time race)
                    self._worker.try_submit(job)
                else:
                    # no admission: the bounded put blocking the producer
                    # is the only brake left
                    self._worker.submit(job)
            except InboxFull:
                shed = RequestShed(
                    f"backpressure: dispatch inbox at capacity "
                    f"({self._worker.capacity})")
                with self._lock:
                    self.stats["shed"] += len(batch)
                for p in batch:
                    self._event_to(job.events, job.dispatch_tick, "shed",
                                   req=p.seq, reason="backpressure")
                    p.future._fail(shed)
            except RuntimeError as exc:
                # worker closed: resolve the popped batch's futures with
                # the cause rather than leaving them pending forever
                for p in batch:
                    p.future._fail(exc)
                raise
        leftover_forced = min(forced, len(group)) - take
        if leftover_forced > 0:
            # forced count exceeded the ladder's top rung: the clamp above
            # kept this batch on a compiled bucket, so the rest of the
            # must-go requests dispatch as follow-on rung-sized batches
            return len(batch) + self._dispatch_group(
                group[take:], forced=leftover_forced)
        return len(batch)

    def _serve(self, reqs: List[EnsembleRequest], batch: List[_Pending],
               exclude: frozenset, masked: frozenset,
               t0: float) -> List[EnsembleResponse]:
        """One engine call for a formed batch — batch-boundary fusion, or
        token-level streaming through the engine's persistent fuser.  The
        streaming path pushes every decode-step emission into the owning
        row's future; member failures (and their hedged retries) happen in
        member generation, *before* fusion starts streaming, so a stream
        never emits tokens for an attempt that is later retried — once
        tokens flow, the member set behind them is final."""
        if self.stream:
            return self.server.serve_requests_stream(
                reqs, on_token=self._stream_push(batch, t0),
                exclude_members=exclude, masked_members=masked,
                capacity=self.stream_capacity,
                prefill_chunk=self.prefill_chunk)
        if exclude or masked:
            return self.server.serve_requests(
                reqs, exclude_members=exclude, masked_members=masked)
        return self.server.serve_requests(reqs)

    def _stream_push(self, batch: List[_Pending], t0: float):
        """Row-indexed ``on_token`` fanning the engine's decode-step
        emissions out to each row's future (plus TTFT capture)."""
        def on_token(i: int, tokens: List[int]) -> None:
            fut = batch[i].future
            if fut.ttft_s is None:
                fut.ttft_s = time.perf_counter() - t0
            fut._push_stream(tokens)
            with self._lock:
                self.stats["stream_tokens"] += 1
        return on_token

    def _serve_batch(self, job: _BatchJob) -> None:
        """Serve one formed batch: the engine call plus hedged retries.
        Runs inline (sync) or on the worker thread (async); every tick
        stamp uses ``job.dispatch_tick``, so both modes write the same
        trace."""
        batch, tick = job.batch, job.dispatch_tick
        exclude: frozenset = frozenset()
        # pre-mask members already known dead (a cluster backend's plan
        # records host deaths), so only the batch in flight at the fault
        # pays a retry — later batches route around the dead host from
        # the start.  The state is SNAPSHOT exactly once per batch, at
        # dispatch time (service entry — inline at dispatch in sync mode;
        # on the FIFO worker in async mode, where every earlier batch has
        # already served, so both modes see the identical view), and the
        # snapshot is an atomic read under the plan's lock: tick-driven
        # revival/rebalance mutating the plan from the caller thread can
        # never tear this batch's masking decisions mid-service.
        dead_hook = getattr(self.server.backend, "dead_members", None)
        masked: frozenset = (frozenset(dead_hook()) if callable(dead_hook)
                             else frozenset())
        reqs = [p.request for p in batch]
        pool_n = self.server.backend.num_members()
        if len(masked) >= pool_n:
            # total outage: every member's placement is dead — fail the
            # batch with a clear cause instead of handing the engine an
            # empty pool to select from
            exc = RuntimeError(
                "no servable pool members: every placement host is dead")
            for p in batch:
                p.future._fail(exc)
            raise exc
        t_serve0 = time.perf_counter()
        while True:
            try:
                responses = self._serve(reqs, batch, exclude, masked, t_serve0)
                break
            except MemberFailure as mf:
                if (not (self.hedge or self.allow_degraded)
                        or len(exclude | masked) + 1 >= pool_n):
                    for p in batch:
                        p.future._fail(mf)
                    raise
                exclude = exclude | {mf.member_idx}
                with self._lock:
                    self.stats["hedges"] += 1
                    self.stats["hedged_requests"] += len(batch)
                self._event_to(job.events, tick, "hedge", member=mf.member_idx,
                               reqs=[p.seq for p in batch],
                               exclude=sorted(exclude))
            except HostFailure as hf:
                dead = frozenset(hf.member_idxs)
                survivors_left = len(exclude | masked | dead) < pool_n
                # `dead <= masked` means no progress: a host that keeps
                # failing without newly killing members would retry forever
                if (not (self.hedge or self.allow_degraded) or not dead
                        or not survivors_left or dead <= masked):
                    for p in batch:
                        p.future._fail(hf)
                    raise
                masked = masked | dead
                with self._lock:
                    self.stats["host_hedges"] += 1
                    self.stats["hedged_requests"] += len(batch)
                self._event_to(job.events, tick, "host_hedge",
                               host=hf.host_id, members=sorted(dead),
                               reqs=[p.seq for p in batch],
                               masked=sorted(masked))
            except Exception as exc:
                # the batch is already popped; resolve every sibling future
                # with the cause instead of leaving them pending forever
                for p in batch:
                    p.future._fail(exc)
                raise
        self._event_to(job.events, tick, "dispatch",
                       reqs=[p.seq for p in batch], size=len(batch),
                       bucket=self.ladder.batch_bucket(len(batch)),
                       exclude=sorted(exclude), masked=sorted(masked))
        n_degraded = sum(1 for r in responses if r.degraded)
        if self.allow_degraded and n_degraded:
            # partial-ensemble settlement: the batch served on survivors,
            # so the rolling ε window charges it against the survivors'
            # full cost (what the re-targeted budget actually constrained)
            # rather than a full-pool cost nothing could have spent
            self._event_to(
                job.events, tick, "degraded",
                reqs=[p.seq for p in batch],
                missing=sorted(set().union(
                    *(r.missing_members for r in responses))),
                realized=float(sum(r.realized_cost for r in responses)),
                survivor_full=float(sum(r.survivor_cost for r in responses)),
                # the batch that actually settled (the survivor retry) —
                # hedged attempts that never served report no padding
                padded=self.ladder.batch_bucket(len(batch)) - len(batch))
        ledger_rows = []
        for p, response in zip(batch, responses):
            missed = (p.deadline_tick is not None and tick > p.deadline_tick)
            if missed:
                p.future.deadline_missed = True
            p.future._set(response)
            # full-ensemble cost backed out of the realized fraction keeps
            # the ledger exact for any policy without a second cost pass;
            # degraded batches settle against the survivors' full cost
            # instead (gated on allow_degraded so legacy ledgers are
            # byte-stable)
            if self.allow_degraded and response.degraded:
                full = response.survivor_cost
            else:
                full = (response.realized_cost / response.cost_fraction
                        if response.cost_fraction > 0 else 0.0)
            ledger_rows.append((tick, response.realized_cost, full))
            if missed:
                self._event_to(job.events, tick, "miss", req=p.seq,
                               deadline=p.deadline_tick)
            self._event_to(job.events, tick, "complete", req=p.seq,
                           latency_ticks=tick - p.arrive_tick,
                           missed=missed, text_digest=_digest(response.text))
        with self._lock:
            self.stats["degraded_responses"] += n_degraded
            self.stats["deadline_misses"] += sum(
                1 for p in batch if p.future.deadline_missed)
            # padding is charged once per *served* dispatch, in this
            # settlement block that runs exactly once per batch — never
            # inside the retry loop, where a hedged re-serve would charge
            # the same rows again (per-attempt padding lives in the
            # engine dispatcher's own stats, where it belongs)
            self.stats["padded_rows"] += (
                self.ladder.batch_bucket(len(batch)) - len(batch))
            self.stats["dispatched_batches"] += 1
            self.stats["dispatched_requests"] += len(batch)
            self._ledger.extend(ledger_rows)
            # entries older than the window can never matter again — prune
            # so the ledger stays O(window), not O(session)
            floor = tick - self._window_ticks()
            self._ledger = [e for e in self._ledger if e[0] > floor]
            # inter-dispatch gap EWMA: the deadline-aware admission's
            # service-time estimate (first dispatch seeds the clock only)
            if self._last_dispatch_tick is not None and self.admission:
                gap = float(tick - self._last_dispatch_tick)
                a = self.admission.service_alpha
                self._service_ewma = (
                    gap if self._service_ewma is None
                    else a * gap + (1.0 - a) * self._service_ewma)
            self._last_dispatch_tick = tick
