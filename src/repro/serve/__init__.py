from repro.serve.api import EnsembleRequest, EnsembleResponse, requests_from_records
from repro.serve.backends import (
    FailureInjector,
    HostFailure,
    LiveLMBackend,
    LiveMember,
    MemberBackend,
    MemberFailure,
    SimBackend,
)
from repro.serve.cluster import (
    ClusterRouter,
    DispatchWorker,
    HostSpec,
    InboxFull,
    MemberPlacement,
    PlacementPlan,
)
from repro.serve.dispatch import (
    BucketLadder,
    DecoderGenerateDispatcher,
    EncDecGenerateDispatcher,
)
from repro.serve.engine import EnsembleServer, ServeResult
from repro.serve.generate import greedy_generate, greedy_generate_encdec, prompt_positions
from repro.serve.scheduler import (
    AdmissionControl,
    RequestShed,
    ResponseFuture,
    Scheduler,
)
from repro.serve.traffic import (
    ArrivalProcess,
    CapturedTrace,
    Scenario,
    TrafficReport,
    TrafficSimulator,
    build_arrivals,
    preset_scenarios,
    replay,
)

__all__ = [
    "AdmissionControl",
    "ArrivalProcess",
    "BucketLadder",
    "CapturedTrace",
    "ClusterRouter",
    "DecoderGenerateDispatcher",
    "DispatchWorker",
    "EncDecGenerateDispatcher",
    "EnsembleRequest",
    "EnsembleResponse",
    "EnsembleServer",
    "FailureInjector",
    "HostFailure",
    "HostSpec",
    "InboxFull",
    "LiveLMBackend",
    "LiveMember",
    "MemberBackend",
    "MemberFailure",
    "MemberPlacement",
    "PlacementPlan",
    "RequestShed",
    "ResponseFuture",
    "Scenario",
    "Scheduler",
    "ServeResult",
    "SimBackend",
    "TrafficReport",
    "TrafficSimulator",
    "build_arrivals",
    "greedy_generate",
    "greedy_generate_encdec",
    "preset_scenarios",
    "prompt_positions",
    "replay",
    "requests_from_records",
]
