from repro.serve.engine import EnsembleServer, LiveMember, ServeResult
from repro.serve.generate import greedy_generate, greedy_generate_encdec, prompt_positions

__all__ = [
    "EnsembleServer",
    "LiveMember",
    "ServeResult",
    "greedy_generate",
    "greedy_generate_encdec",
    "prompt_positions",
]
