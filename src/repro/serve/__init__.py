from repro.serve.api import EnsembleRequest, EnsembleResponse, requests_from_records
from repro.serve.backends import LiveLMBackend, LiveMember, MemberBackend, SimBackend
from repro.serve.dispatch import (
    BucketLadder,
    DecoderGenerateDispatcher,
    EncDecGenerateDispatcher,
)
from repro.serve.engine import EnsembleServer, ServeResult
from repro.serve.generate import greedy_generate, greedy_generate_encdec, prompt_positions
from repro.serve.scheduler import ResponseFuture, Scheduler

__all__ = [
    "BucketLadder",
    "DecoderGenerateDispatcher",
    "EncDecGenerateDispatcher",
    "EnsembleRequest",
    "EnsembleResponse",
    "EnsembleServer",
    "LiveLMBackend",
    "LiveMember",
    "MemberBackend",
    "ResponseFuture",
    "Scheduler",
    "ServeResult",
    "SimBackend",
    "greedy_generate",
    "greedy_generate_encdec",
    "prompt_positions",
    "requests_from_records",
]
