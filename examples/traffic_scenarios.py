"""Drive the serving stack through the preset traffic scenarios and
print the SLO summary each produces — then prove the stream is exactly
the offline batch in disguise.

    PYTHONPATH=src python examples/traffic_scenarios.py [--n 16]

Per scenario: p50/p99 request latency, deadline-miss rate, shed rate,
hedged retries, and steady-state recompiles.  The ``failure`` scenario
injects a mid-batch backend fault (hedged retry re-serves the batch on
the surviving members); ``host-outage`` kills a whole placement host
(the knapsack re-solves over the surviving members); ``host-recovery``
revives the dead host after a probation window mid-run; ``diurnal``
drives a sinusoidal day/night load curve.  Every request resolves in
all of them.
"""

import argparse

from repro.core import make_policy
from repro.data import DEFAULT_POOL, generate_dataset
from repro.launch.serve import build_stack
from repro.serve import (
    AdmissionControl,
    EnsembleServer,
    Scheduler,
    TrafficSimulator,
    preset_scenarios,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16, help="requests per scenario")
    ap.add_argument("--budget", type=float, default=0.2)
    ap.add_argument("--train-steps", type=int, default=0)
    args = ap.parse_args()

    _, _, _, fuser, fuser_p, predictor, pred_p = build_stack(args.train_steps)
    records = generate_dataset(args.n, seed=11)

    print(f"{args.n} requests per scenario, budget = {args.budget:.0%}\n")
    for name, scenario in preset_scenarios(n_requests=args.n).items():
        server = EnsembleServer(DEFAULT_POOL, make_policy("modi", budget=args.budget),
                                predictor, pred_p, fuser, fuser_p)
        rungs = sorted({server.bucket_ladder.batch_bucket(b) for b in range(1, 5)})
        server.warm([(b, server.max_new_tokens) for b in rungs])
        warm = server.generate_compiles()["total"]
        scheduler = Scheduler(server, max_batch_size=4, max_wait_ticks=2,
                              admission=AdmissionControl(window_ticks=4))
        report = TrafficSimulator(scheduler, scenario, records).run()
        pct = report.latency_percentiles()
        print(f"{name:>10}: served {report.served}/{report.n} "
              f"in {report.ticks} ticks, "
              f"p50={pct['p50_latency_s']*1e3:.0f}ms "
              f"p99={pct['p99_latency_s']*1e3:.0f}ms "
              f"miss={report.deadline_miss_rate:.0%} "
              f"shed={report.shed_rate:.0%} "
              f"hedges={report.stats['hedges']} "
              f"host_hedges={report.stats['host_hedges']} "
              f"recompiles={report.compiles['total'] - warm}")

        # the stream is the offline batch in disguise: byte-identical
        # (fault-injecting scenarios hedge mid-run, so their hedged
        # batches intentionally diverge from the plain offline solve)
        offline_server = EnsembleServer(
            DEFAULT_POOL, make_policy("modi", budget=args.budget),
            predictor, pred_p, fuser, fuser_p)
        if not scenario.failures and not scenario.host_failures:
            offline = offline_server.serve_requests(report.requests)
            assert [r.text for r in report.responses] == [r.text for r in offline]
    print("\nevery scenario's stream matched its offline batch byte for byte")


if __name__ == "__main__":
    main()
