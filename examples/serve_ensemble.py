"""Serve a request stream through the MODI engine two ways — one offline
batch call and one request at a time through the admission Scheduler —
verify they produce identical fused responses, then compare the paper's
policy against every baseline at equal budget (paper §3).

    PYTHONPATH=src python examples/serve_ensemble.py [--train-steps 200]
"""

import argparse

import numpy as np

from repro.core import make_policy
from repro.data import DEFAULT_POOL, generate_dataset
from repro.launch.serve import build_stack
from repro.serve import EnsembleServer, Scheduler, requests_from_records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=0)
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--budget", type=float, default=0.2)
    args = ap.parse_args()

    _, scorer, scorer_p, fuser, fuser_p, predictor, pred_p = build_stack(args.train_steps)
    policies = [
        make_policy("modi", budget=args.budget),
        make_policy("greedy-ratio", budget=args.budget),
        make_policy("random", k=3),
        make_policy("best-single"),
        make_policy("hybrid-router", small_index=7, large_index=1),
        make_policy("llm-blender"),
    ]
    batch = generate_dataset(args.n, seed=11)
    print(f"{args.n} queries, budget = {args.budget:.0%} of full-ensemble cost\n")

    # 1. offline batch vs one-request-at-a-time through the Scheduler: the
    #    engine's request path is deterministic, so the outputs must match.
    server = EnsembleServer(DEFAULT_POOL, policies[0], predictor, pred_p, fuser, fuser_p)
    offline = server.serve(batch)
    scheduler = Scheduler(server, max_batch_size=4, max_wait_ticks=2)
    futures = [scheduler.submit(req) for req in requests_from_records(batch)]
    scheduler.flush()
    online = [f.result() for f in futures]
    assert [r.text for r in online] == offline.responses, "scheduler != batch path"
    assert all((r.mask == offline.mask[i]).all() for i, r in enumerate(online))
    print(f"scheduler path == batch path over {args.n} requests "
          f"({scheduler.stats['dispatched_batches']} micro-batches)\n")

    # 2. every baseline at equal budget through the same engine
    for policy in policies:
        server = EnsembleServer(DEFAULT_POOL, policy, predictor, pred_p, fuser, fuser_p)
        res = server.serve(batch)
        print(f"{policy.name:>14}: mean members={res.mask.sum(1).mean():.1f} "
              f"cost={res.cost_fraction.mean():.2f}x-full "
              f"example={res.responses[0]!r}")


if __name__ == "__main__":
    main()
