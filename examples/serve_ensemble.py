"""Serve a batched request stream through the MODI engine and compare the
paper's policy against every baseline at equal budget (paper §3).

    PYTHONPATH=src python examples/serve_ensemble.py [--train-steps 200]
"""

import argparse

import numpy as np

from repro.core import (
    BestSinglePolicy,
    EpsilonConstraint,
    FullEnsemblePolicy,
    GreedyRatioPolicy,
    HybridRouterPolicy,
    ModiPolicy,
    RandomPolicy,
)
from repro.data import DEFAULT_POOL, generate_dataset
from repro.launch.serve import build_stack
from repro.serve import EnsembleServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=0)
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--budget", type=float, default=0.2)
    args = ap.parse_args()

    _, scorer, scorer_p, fuser, fuser_p, predictor, pred_p = build_stack(args.train_steps)
    eps = EpsilonConstraint(args.budget)
    policies = [
        ModiPolicy(eps),
        GreedyRatioPolicy(eps),
        RandomPolicy(k=3),
        BestSinglePolicy(),
        HybridRouterPolicy(small_index=7, large_index=1),
        FullEnsemblePolicy(),
    ]
    batch = generate_dataset(args.n, seed=11)
    print(f"{args.n} queries, budget = {args.budget:.0%} of full-ensemble cost\n")
    for policy in policies:
        server = EnsembleServer(DEFAULT_POOL, policy, predictor, pred_p, fuser, fuser_p)
        res = server.serve(batch)
        print(f"{policy.name:>14}: mean members={res.mask.sum(1).mean():.1f} "
              f"cost={res.cost_fraction.mean():.2f}x-full "
              f"example={res.responses[0]!r}")


if __name__ == "__main__":
    main()
