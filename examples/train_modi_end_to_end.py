"""End-to-end driver (deliverable b): trains EVERY trainable component of
the MODI stack for a few hundred steps on CPU and then serves with it —
the live-model path (no behavioral simulation).

    PYTHONPATH=src python examples/train_modi_end_to_end.py [--steps 300] [--members 3]

Stages:
  1. BARTScore scorer (enc-dec conditional-LL metric model)
  2. GEN-FUSER (fusion enc-dec)
  3. tiny pool-member LMs trained per competence profile (live pool)
  4. BARTScore labels for member responses
  5. MODI DeBERTa-style predictor (Huber d=0.3, Adam 3e-4/0.9/0.98/wd 0.01)
  6. serve a held-out batch under a 20% budget
"""

import argparse

import jax
import numpy as np

from repro import configs
from repro.core import make_policy
from repro.data import DEFAULT_POOL, generate_dataset, lm_batches
from repro.launch.serve import build_stack
from repro.models import build_model
from repro.optim import AdamW
from repro.serve import EnsembleServer, LiveMember
from repro.train import repeat_batches, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--members", type=int, default=3, help="live members to train (rest simulated)")
    ap.add_argument("--budget", type=float, default=0.2)
    args = ap.parse_args()

    recs, scorer, scorer_p, fuser, fuser_p, predictor, pred_p = build_stack(args.steps)

    # live pool members: tiny llama-family LMs trained on competence-weighted data
    member_cfg = configs.get("smollm-360m").reduced(
        dtype="float32", vocab_size=512, d_model=128, num_layers=2
    )
    live = []
    for j, spec in enumerate(DEFAULT_POOL[: args.members]):
        print(f"[pool] training live member {spec.name} ({args.steps} steps)")
        model = build_model(member_cfg)
        params = model.init(jax.random.key(100 + j))
        params = train(
            lambda p, b: model.loss(p, b), params,
            repeat_batches(lambda ep, s=spec: lm_batches(recs, 16, 96, seed=ep, member=s)),
            args.steps, optimizer=AdamW(learning_rate=2e-3),
        ).params
        live.append(LiveMember(spec=spec, model=model, params=params))

    # hybrid pool: first --members live, rest behavioral (documented in DESIGN.md)
    server = EnsembleServer(
        DEFAULT_POOL, make_policy("modi", budget=args.budget),
        predictor, pred_p, fuser, fuser_p,
        live_members=None,  # selection/fusion path; member gen below shows live models
    )
    held_out = generate_dataset(8, seed=4242)
    result = server.serve(held_out)
    print("\n=== MODI serving (predictor + knapsack + fuse) ===")
    for rec, resp, frac in zip(held_out, result.responses, result.cost_fraction):
        print(f"Q: {rec.query!r} -> {resp!r}  ({frac:.0%} of full cost)")

    print("\n=== live member generations (trained tiny LMs) ===")
    from repro.data import TOKENIZER
    from repro.serve import greedy_generate
    prompts = [TOKENIZER.encode(r.query, bos=True) + [TOKENIZER.sep_id] for r in held_out[:4]]
    batch = TOKENIZER.pad_batch(prompts, 96)
    for lm in live:
        outs = greedy_generate(lm.model, lm.params, batch, max_new=24)
        print(f"[{lm.spec.name}]")
        for r, o in zip(held_out[:4], outs):
            print(f"   {r.query!r} -> {TOKENIZER.decode(o)!r} (ref {r.reference!r})")


if __name__ == "__main__":
    main()
