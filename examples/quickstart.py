"""Quickstart: the paper's pipeline in ~60 lines.

Builds the bi-objective problem for a batch of queries, applies the
ε-constraint (knapsack) at several budgets, and shows the quality-cost
frontier — no training required (uses oracle quality scores).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import make_policy, realized_cost_fraction
from repro.data import DEFAULT_POOL, generate_dataset, query_cost_matrix

# 1. queries + the paper's 8-member pool with Kaplan costs (Eq. 1)
records = generate_dataset(16, seed=0)
costs = query_cost_matrix(DEFAULT_POOL, records)  # [Q, N] FLOPs = c_i * t_i(q)
print("pool:", [m.name for m in DEFAULT_POOL])
print(f"cost per query, full ensemble: {costs.sum(1).mean():.3g} FLOPs")

# 2. oracle quality r(m_i, q) (BARTScore-like, negative; higher = better).
#    In the full system these come from the MODI DeBERTa predictor.
rng = np.random.default_rng(0)
quality = np.array([
    [-4.0 + 2.0 * m.competence[r.domain_id] + 0.1 * rng.standard_normal()
     for m in DEFAULT_POOL] for r in records
], np.float32)

# 3. ε-constrained selection at a sweep of budgets (paper §2.2).
#    Report the BEST member quality selected (what the fuser builds on) and
#    the alpha-shifted knapsack profit the DP maximizes (Eq. 4).
from repro.core import shift_scores

profits = np.asarray(shift_scores(jnp.asarray(quality))[0])
for frac in (0.1, 0.2, 0.5, 1.0):
    policy = make_policy("modi", budget=frac)
    mask = np.asarray(policy.select(jnp.asarray(quality), jnp.asarray(costs)))
    best = np.where(mask, quality, -np.inf).max(1).mean()
    profit = np.where(mask, profits, 0).sum(1).mean()
    spent = float(realized_cost_fraction(jnp.asarray(mask), jnp.asarray(costs)).mean())
    k = mask.sum(1).mean()
    print(f"eps={frac:>4}: avg members={k:.1f}  spent={spent:.2f}x-full  "
          f"best-member quality={best:.2f}  knapsack profit={profit:.2f}")

# 4. versus baselines at the paper's operating point (20% of blender cost)
for policy in (make_policy("modi", budget=0.2),
               make_policy("greedy-ratio", budget=0.2),
               make_policy("llm-blender")):
    mask = np.asarray(policy.select(jnp.asarray(quality), jnp.asarray(costs)))
    best = np.where(mask, quality, -np.inf).max(1).mean()
    spent = float(realized_cost_fraction(jnp.asarray(mask), jnp.asarray(costs)).mean())
    print(f"{policy.name:>14}: best-member quality={best:.2f} at {spent:.2f}x full-ensemble cost")
