"""Bi-objective Pareto frontier via the ε-constraint sweep (paper §2.1-2.2).

Shows that sweeping ε over the knapsack recovers exactly the non-dominated
(cost, quality) points that brute-force enumeration finds.

    PYTHONPATH=src python examples/pareto_sweep.py
"""

import numpy as np

from repro.core import enumerate_pareto, pareto_sweep
from repro.data import DEFAULT_POOL, generate_dataset, query_cost_matrix

records = generate_dataset(3, seed=7)
costs = query_cost_matrix(DEFAULT_POOL, records)
rng = np.random.default_rng(7)

for qi, rec in enumerate(records):
    quality = np.array(
        [-4.0 + 2.0 * m.competence[rec.domain_id] + 0.05 * rng.standard_normal()
         for m in DEFAULT_POOL], np.float32
    )
    print(f"\nQ{qi}: {rec.query!r}")
    # ground truth: brute-force all 2^8 subsets
    shifted = quality - quality.min() + 0.01  # alpha-shift (Eq. 4)
    truth = enumerate_pareto(shifted, costs[qi])
    print(f"  brute-force frontier: {len(truth)} points")
    # epsilon sweep (the paper's reduction)
    frontier = pareto_sweep(quality, costs[qi], fractions=np.linspace(0.02, 1.0, 50))
    print("  eps-sweep frontier (cost_frac, total_quality, members):")
    for cf, q, mask in frontier:
        names = [DEFAULT_POOL[i].name.split("-")[0] for i in range(len(mask)) if mask[i]]
        print(f"    {cf:5.2f}  {q:7.2f}  {names}")
